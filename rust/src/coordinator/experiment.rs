//! Experiment configurations and the campaign runner.
//!
//! A *campaign* = one experimental configuration (platform, personas,
//! iteration budget, profiling on/off, reference on/off, baseline)
//! over a suite.  Each (persona, problem) job runs the full §3 loop:
//!
//! ```text
//! iteration 0: F(p) → k₀ → verify
//! functional pass: while not correct: F(p, kₜ₋₁, error) → kₜ
//! optimization pass: G(evidence) → r; F(p, kₜ₋₁, r) → kₜ  (keep best)
//! ```
//!
//! The profiling step is frontend-agnostic: the platform's registered
//! `ProfilerFrontend` captures the profile into its native artifact and
//! interprets it into the `Evidence` IR; the analysis agent ranks from
//! evidence alone.

use super::job::TaskResult;
use crate::agents::analysis::AnalysisAgent;
use crate::obs;
use crate::agents::{GenerationAgent, Persona, Program};
use crate::baseline::{autotuned, compilebase, eager};
use crate::metrics::TaskOutcome;
use crate::platform::{PlatformRef, PlatformSpec};
use crate::profiler::Profile;
use crate::store::{CacheStats, JobKey, Journal, KeyScope, Store};
use crate::util::rng::Pcg;
use crate::verify::{self, ExecState};
use crate::workloads::refcorpus::RefCorpus;
use crate::workloads::{Problem, Suite};
use std::sync::atomic::{AtomicU64, Ordering};

/// Which baseline the speedup is computed against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    /// PyTorch eager mode (Fig 2, Fig 4, Tables 4–6).
    Eager,
    /// torch.compile / TorchInductor default (Fig 3, Table 6).
    TorchCompile,
    /// The schedule the beam autotuner finds for the workload
    /// (`kforge run --baseline autotuned`): speedups against the
    /// best-effort non-agent search instead of naive/stock baselines.
    Autotuned,
}

/// One experimental configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    /// The target platform, resolved through the registry.
    pub platform: PlatformRef,
    pub personas: Vec<&'static Persona>,
    /// Total iterations (1 = single-shot; the paper uses 5).
    pub iterations: usize,
    /// Feed profiling data through the analysis agent G.
    pub use_profiling: bool,
    /// Provide CUDA reference implementations (cross-platform transfer,
    /// §6.2).
    pub use_reference: bool,
    pub baseline: BaselineKind,
    pub seed: u64,
    /// Worker threads (devices); paper used 4 GPUs / 5 Mac Studios.
    pub workers: usize,
}

impl ExperimentConfig {
    pub fn spec(&self) -> PlatformSpec {
        self.platform.spec().clone()
    }

    /// The paper's default iterative-refinement configuration on any
    /// registered platform.
    pub fn iterative(platform: PlatformRef, personas: Vec<&'static Persona>) -> ExperimentConfig {
        ExperimentConfig {
            name: format!("{}_iterative", platform.name()),
            workers: platform.default_workers(),
            platform,
            personas,
            iterations: 5,
            use_profiling: false,
            use_reference: false,
            baseline: BaselineKind::Eager,
            seed: 0x5EED,
        }
    }

    /// The paper's default CUDA iterative-refinement configuration.
    pub fn cuda_iterative(personas: Vec<&'static Persona>) -> ExperimentConfig {
        let mut cfg = Self::iterative(
            crate::platform::by_name("cuda").expect("builtin cuda"),
            personas,
        );
        cfg.name = "cuda_iterative".into();
        cfg
    }

    /// The paper's default MPS configuration.
    pub fn mps_iterative(personas: Vec<&'static Persona>) -> ExperimentConfig {
        let mut cfg = Self::iterative(
            crate::platform::by_name("metal").expect("builtin metal"),
            personas,
        );
        cfg.name = "mps_iterative".into();
        cfg
    }
}

/// Campaign output: all task results plus the config that produced them.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub config_name: String,
    pub results: Vec<TaskResult>,
    /// Result-store counters for this campaign: how many jobs were
    /// answered from the cache, restored from a `--resume` journal, or
    /// actually computed (all zeros when the store is disabled).
    pub cache: CacheStats,
}

impl CampaignResult {
    /// Outcomes for one persona at one level.
    pub fn outcomes(&self, persona: &str, level: crate::workloads::Level) -> Vec<TaskOutcome> {
        self.results
            .iter()
            .filter(|r| r.persona == persona && r.level == level)
            .map(|r| r.outcome)
            .collect()
    }

    /// Execution-state census across all iterations (the §3.3 logs).
    pub fn state_census(&self) -> std::collections::BTreeMap<&'static str, usize> {
        let mut m = std::collections::BTreeMap::new();
        for r in &self.results {
            for s in &r.state_history {
                *m.entry(*s).or_insert(0) += 1;
            }
        }
        m
    }
}

/// Run one (persona, problem) job: the full iterative loop.
pub fn run_task(
    cfg: &ExperimentConfig,
    spec: &PlatformSpec,
    persona: &'static Persona,
    problem: &Problem,
    reference: Option<&Program>,
) -> TaskResult {
    let _task_span = obs::span("task.run");
    // deterministic per-(config, persona, problem) stream
    let mut rng = Pcg::new(
        cfg.seed ^ crate::util::rng::fnv1a(cfg.name.as_bytes()),
        crate::util::rng::fnv1a(format!("{}::{}", persona.name, problem.id).as_bytes()),
    );
    let agent = GenerationAgent::new(persona, cfg.platform.clone());
    let analyst = AnalysisAgent::new(cfg.platform.clone());

    // baseline measurement (compilation context reset per run — fresh RNG)
    let mut brng = rng.fork("baseline");
    let baseline_sim = {
        let _s = obs::span("task.baseline");
        match cfg.baseline {
            BaselineKind::Eager => eager::measure(&problem.perf_graph, spec, &mut brng),
            BaselineKind::TorchCompile => compilebase::measure(&problem.perf_graph, spec, &mut brng),
            BaselineKind::Autotuned => autotuned::measure(&problem.perf_graph, spec, &mut brng),
        }
    };
    let baseline_s = baseline_sim.measured_s;

    let mut state_history = Vec::with_capacity(cfg.iterations);
    let mut best: Option<(f64, usize)> = None; // (candidate seconds, iteration)
    let mut current: Option<Program> = None;
    let mut last_error: Option<String> = None;
    let mut last_rec: Option<crate::agents::Recommendation> = None;

    for iter in 0..cfg.iterations {
        let candidate = {
            let _s = obs::span("task.synthesize");
            match (&current, &last_error) {
                (None, _) => agent.synthesize(problem, reference, &mut rng),
                (Some(prev), Some(err)) => agent.refine(problem, prev, Some(err), None, &mut rng),
                (Some(prev), None) => {
                    let rec = if cfg.use_profiling { last_rec.as_ref() } else { None };
                    agent.refine(problem, prev, None, rec, &mut rng)
                }
            }
        };
        let out = {
            let _s = obs::span("task.verify");
            verify::verify(spec, problem, candidate.as_ref(), &mut rng)
        };
        state_history.push(out.state.label());
        match out.state {
            ExecState::Correct => {
                let sim = out.sim.expect("correct implies sim");
                let t = sim.measured_s;
                if best.map(|(b, _)| t < b).unwrap_or(true) {
                    best = Some((t, iter));
                }
                // profile → frontend capture → Evidence → one
                // recommendation for the next iteration; a capture the
                // frontend could not interpret carries zero confidence
                // and is withheld (no evidence ⇒ no recommendation)
                if cfg.use_profiling {
                    if let Some(prog) = &candidate {
                        let _s = obs::span("task.profile");
                        let profile = Profile::from_sim(&problem.id, spec.name, &sim);
                        let advice = analyst.advise(&profile, &prog.schedule);
                        last_rec = if advice.confidence > 0.0 {
                            Some(advice.recommendation)
                        } else {
                            None
                        };
                    }
                }
                last_error = None;
                current = candidate;
            }
            ref failed => {
                last_error = failed.error_text().map(|s| s.to_string());
                last_rec = None;
                if candidate.is_some() {
                    current = candidate;
                }
            }
        }
    }

    let outcome = match best {
        Some((t, _)) => TaskOutcome::correct(baseline_s / t),
        None => TaskOutcome::incorrect(),
    };
    TaskResult {
        problem_id: problem.id.clone(),
        level: problem.level,
        persona: persona.name,
        state_history,
        outcome,
        best_iteration: best.map(|(_, i)| i),
        baseline_s,
        best_candidate_s: best.map(|(t, _)| t),
    }
}

/// The canonical campaign job list: persona × problem over an
/// already-filtered suite (the caller applies `supported_on`),
/// references resolved up front (the reference is part of a job's
/// identity).  This enumeration order IS the job index space — the
/// journal format, the shard planner (`crate::dist`) and the merge
/// phase all key records by position in this list, so it must stay
/// deterministic and shared across every execution mode.
pub(crate) fn job_list<'a>(
    cfg: &ExperimentConfig,
    filtered: &'a Suite,
    corpus: Option<&'a RefCorpus>,
) -> Vec<(&'static Persona, &'a Problem, Option<&'a Program>)> {
    cfg.personas
        .iter()
        .flat_map(|p| {
            filtered.problems.iter().map(move |pr| {
                let reference = if cfg.use_reference {
                    corpus.and_then(|c| c.get(&pr.id))
                } else {
                    None
                };
                (*p, pr, reference)
            })
        })
        .collect()
}

/// Run a full campaign over a suite, distributing jobs across the
/// worker pool (one job per simulated device at a time), consulting
/// the process-wide result store (see [`crate::store::global`] — a
/// pass-through unless the CLI configured one).
pub fn run_campaign(
    suite: &Suite,
    corpus: Option<&RefCorpus>,
    cfg: &ExperimentConfig,
) -> CampaignResult {
    run_campaign_with(crate::store::global(), suite, corpus, cfg)
}

/// [`run_campaign`] against an explicit result store.  The store is
/// consulted *before* dispatch (hits never reach the worker pool) and
/// written back as each computed job completes; with journaling
/// enabled, every completion is also appended to the campaign journal
/// so a killed campaign resumes from the last completed job.
///
/// Substituting a stored result is safe because job results are
/// bit-identical across worker counts and scheduling (the PR 3
/// property pinned in the tests below), and the [`JobKey`] covers
/// everything a result depends on.
pub fn run_campaign_with(
    store: &Store,
    suite: &Suite,
    corpus: Option<&RefCorpus>,
    cfg: &ExperimentConfig,
) -> CampaignResult {
    let spec = cfg.spec();
    let filtered = suite.supported_on(&spec);
    let jobs = job_list(cfg, &filtered, corpus);
    let workers = cfg.workers.max(1);
    let _campaign_span = obs::span("campaign");
    if !store.enabled() {
        let indices: Vec<usize> = (0..jobs.len()).collect();
        let results = super::worker::run_sparse(workers, &indices, |i| {
            let (persona, problem, reference) = jobs[i];
            let _lane = obs::job_lane(spec.name, persona.name, &problem.id);
            run_task(cfg, &spec, persona, problem, reference)
        });
        trace_task_results(spec.name, &results);
        return CampaignResult {
            config_name: cfg.name.clone(),
            results,
            cache: CacheStats::default(),
        };
    }

    let consult_span = obs::span("campaign.consult");
    let scope = KeyScope::new(cfg, &spec);
    let keys: Vec<JobKey> = jobs
        .iter()
        .map(|(persona, problem, reference)| scope.key(persona, problem, *reference))
        .collect();
    let mut stats = CacheStats::default();
    let mut slots: Vec<Option<TaskResult>> = vec![None; jobs.len()];

    // 1. restore completed jobs from the campaign journal (--resume);
    //    without resume, start the journal fresh.  Journal I/O failures
    //    are logged and never fail the campaign.
    let journal: Option<Journal> = store.journal_path(&cfg.name, &keys).and_then(|path| {
        let opened = if store.resume() {
            Journal::resume(&path, &cfg.name, &keys).map(|(j, restored)| {
                for (i, r) in restored {
                    stats.resumed += 1;
                    store.record_resumed();
                    stats.bytes_written += store.put(&keys[i], &r);
                    slots[i] = Some(r);
                }
                j
            })
        } else {
            Journal::fresh(&path, &cfg.name, &keys)
        };
        match opened {
            Ok(j) => Some(j),
            Err(e) => {
                crate::kf_warn!("[store] campaign journal unavailable ({e:#}); continuing without it");
                None
            }
        }
    });

    // 2. consult the store before dispatch; cache hits not already in
    //    the journal are backfilled so the journal converges to a
    //    complete record of the campaign.
    let mut backfill: Vec<usize> = Vec::new();
    for (i, slot) in slots.iter_mut().enumerate() {
        if slot.is_none() {
            if let Some((r, bytes)) = store.get(&keys[i]) {
                stats.hits += 1;
                stats.bytes_read += bytes;
                *slot = Some(r);
                backfill.push(i);
            }
        }
    }
    if let Some(j) = &journal {
        for &i in &backfill {
            if let Err(e) = j.append(i, &keys[i], slots[i].as_ref().expect("backfilled slot")) {
                crate::kf_warn!("[store] journal backfill failed ({e:#})");
                break;
            }
        }
    }
    drop(consult_span);

    // 3. compute what remains, writing back (store + journal) as each
    //    job completes so a kill loses at most the in-flight jobs.
    let pending: Vec<usize> = slots
        .iter()
        .enumerate()
        .filter_map(|(i, s)| s.is_none().then_some(i))
        .collect();
    stats.misses = pending.len() as u64;
    let bytes_written = AtomicU64::new(0);
    let dispatch_span = obs::span("campaign.dispatch");
    let computed = super::worker::run_sparse(workers, &pending, |i| {
        let (persona, problem, reference) = jobs[i];
        let _lane = obs::job_lane(spec.name, persona.name, &problem.id);
        let r = run_task(cfg, &spec, persona, problem, reference);
        {
            let _s = obs::span("task.store");
            bytes_written.fetch_add(store.put(&keys[i], &r), Ordering::Relaxed);
            if let Some(j) = &journal {
                if let Err(e) = j.append(i, &keys[i], &r) {
                    crate::kf_warn!("[store] journal append failed for job {i} ({e:#})");
                }
            }
        }
        r
    });
    drop(dispatch_span);
    for (i, r) in pending.into_iter().zip(computed) {
        slots[i] = Some(r);
    }
    stats.bytes_written += bytes_written.into_inner();
    let results: Vec<TaskResult> = slots
        .into_iter()
        .map(|s| s.expect("every job slot filled after dispatch"))
        .collect();
    trace_task_results(spec.name, &results);
    CampaignResult {
        config_name: cfg.name.clone(),
        results,
        cache: stats,
    }
}

/// Emit the logical (determinism-digest) view of a campaign: one
/// job-identity lane per job with the task's pinned result fields as
/// logical events.  Emitted *post-hoc from the assembled results* —
/// never from live execution — so the stream is bit-identical whether
/// a job was computed, cache-answered or journal-restored, which is
/// exactly the warm-vs-cold guarantee `Snapshot::canon` pins.
fn trace_task_results(platform: &str, results: &[TaskResult]) {
    if !obs::enabled() {
        return;
    }
    for r in results {
        let _lane = obs::job_lane(platform, r.persona, &r.problem_id);
        let _span = obs::logical_span(&format!("task:{}:{}", r.persona, r.problem_id));
        obs::logical_instant(if r.outcome.correct { "task.correct" } else { "task.incorrect" });
        obs::logical_counter("task.iterations", r.state_history.len() as u64);
        obs::logical_gauge("task.speedup", r.outcome.speedup);
        obs::logical_gauge("task.baseline_s", r.baseline_s);
        if let Some(t) = r.best_candidate_s {
            obs::logical_gauge("task.best_candidate_s", t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::persona::by_name;
    use crate::metrics;
    use crate::platform::metal;
    use crate::workloads::Level;

    fn small_cfg(platform: &str, iterations: usize) -> ExperimentConfig {
        ExperimentConfig {
            name: "test".into(),
            platform: crate::platform::by_name(platform).unwrap(),
            personas: vec![by_name("openai-gpt-5").unwrap()],
            iterations,
            use_profiling: false,
            use_reference: false,
            baseline: BaselineKind::Eager,
            seed: 77,
            workers: 2,
        }
    }

    #[test]
    fn campaign_runs_and_is_deterministic() {
        let suite = Suite::sample(3);
        let cfg = small_cfg("cuda", 2);
        let a = run_campaign(&suite, None, &cfg);
        let b = run_campaign(&suite, None, &cfg);
        assert_eq!(a.results.len(), 9);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.problem_id, y.problem_id);
            assert_eq!(x.state_history, y.state_history);
            assert_eq!(x.outcome, y.outcome);
        }
    }

    #[test]
    fn campaign_bitwise_identical_across_worker_pool_sizes() {
        // the worker.rs doc comment claims pool size never changes
        // results; pin that for the real §3 loop (not just a pure
        // closure): every field of every TaskResult, f64s compared by
        // bit pattern, for 1, 4 and 16 workers on the same config
        let suite = Suite::sample(3);
        let mut base = small_cfg("cuda", 2);
        base.personas = vec![
            by_name("openai-gpt-5").unwrap(),
            by_name("deepseek-v3").unwrap(),
        ];
        let runs: Vec<CampaignResult> = [1usize, 4, 16]
            .iter()
            .map(|&w| {
                let mut cfg = base.clone();
                cfg.workers = w;
                run_campaign(&suite, None, &cfg)
            })
            .collect();
        assert_eq!(runs[0].results.len(), 18); // 2 personas × 9 problems
        for run in &runs[1..] {
            assert_eq!(run.results.len(), runs[0].results.len());
            for (a, b) in runs[0].results.iter().zip(&run.results) {
                assert_bit_identical(a, b);
            }
        }
        // worker-count invariance is what makes cached substitution
        // safe; close the loop by pinning the warm-vs-cold half too: a
        // campaign answered entirely from the store is bit-identical
        // to the cold run above, field by field, f64s by bit pattern
        let store = Store::memory();
        let mut warm_cfg = base.clone();
        warm_cfg.workers = 4;
        let first = run_campaign_with(&store, &suite, None, &warm_cfg);
        assert_eq!(first.cache.misses, 18, "cold store must compute every job");
        assert_eq!(first.cache.hits, 0);
        let warm = run_campaign_with(&store, &suite, None, &warm_cfg);
        assert_eq!(warm.cache.hits, 18, "warm store must answer every job");
        assert_eq!(warm.cache.misses, 0);
        for (a, b) in runs[0].results.iter().zip(&warm.results) {
            assert_bit_identical(a, b);
        }
        // the disabled-store (cold) path reports all-zero counters
        assert_eq!(runs[0].cache, CacheStats::default());
    }

    fn assert_bit_identical(a: &TaskResult, b: &TaskResult) {
        assert_eq!(a.problem_id, b.problem_id);
        assert_eq!(a.persona, b.persona);
        assert_eq!(a.level, b.level);
        assert_eq!(a.state_history, b.state_history);
        assert_eq!(a.outcome.correct, b.outcome.correct, "{}", a.problem_id);
        assert_eq!(
            a.outcome.speedup.to_bits(),
            b.outcome.speedup.to_bits(),
            "{}",
            a.problem_id
        );
        assert_eq!(a.best_iteration, b.best_iteration);
        assert_eq!(a.baseline_s.to_bits(), b.baseline_s.to_bits());
        assert_eq!(
            a.best_candidate_s.map(f64::to_bits),
            b.best_candidate_s.map(f64::to_bits)
        );
    }

    #[test]
    fn store_shares_jobs_across_overlapping_suites() {
        // per-job keys are independent of the suite that contains the
        // job, so a campaign over a superset suite reuses the subset's
        // results — this is exactly how `kforge conformance` and
        // `kforge bench` stop recomputing shared jobs in one process
        let store = Store::memory();
        let cfg = small_cfg("cuda", 2);
        let small = run_campaign_with(&store, &Suite::sample(2), None, &cfg);
        assert_eq!(small.cache.misses, 6);
        let big = run_campaign_with(&store, &Suite::sample(3), None, &cfg);
        assert_eq!(big.results.len(), 9);
        assert_eq!(big.cache.hits, 6, "subset jobs must be reused");
        assert_eq!(big.cache.misses, 3);
        // reused results are bit-identical to a cold run of the big suite
        let cold = run_campaign_with(&Store::disabled(), &Suite::sample(3), None, &cfg);
        for (a, b) in cold.results.iter().zip(&big.results) {
            assert_bit_identical(a, b);
        }
    }

    #[test]
    fn autotuned_baseline_is_a_harder_comparator_than_eager() {
        let suite = Suite::sample(3);
        let eager_cfg = small_cfg("cuda", 2);
        let mut auto_cfg = eager_cfg.clone();
        auto_cfg.baseline = BaselineKind::Autotuned;
        let e = run_campaign(&suite, None, &eager_cfg);
        let a = run_campaign(&suite, None, &auto_cfg);
        assert_eq!(e.results.len(), a.results.len());
        let mut strictly_harder = 0;
        for (x, y) in e.results.iter().zip(&a.results) {
            assert_eq!(x.problem_id, y.problem_id);
            // the baseline kind must not perturb the candidate stream
            // (the baseline draws from a forked RNG)
            assert_eq!(x.state_history, y.state_history, "{}", x.problem_id);
            // the tuned baseline prices at or below eager with the same
            // noise stream, so per-job speedups can only shrink
            assert!(
                y.baseline_s <= x.baseline_s,
                "{}: autotuned baseline {} above eager {}",
                x.problem_id,
                y.baseline_s,
                x.baseline_s
            );
            if x.outcome.correct {
                assert!(y.outcome.speedup <= x.outcome.speedup, "{}", x.problem_id);
                if y.outcome.speedup < x.outcome.speedup {
                    strictly_harder += 1;
                }
            }
        }
        assert!(strictly_harder > 0, "the autotuned arm never tightened a speedup");
        // and the arm is deterministic like every other campaign
        let b = run_campaign(&suite, None, &auto_cfg);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.outcome.speedup.to_bits(), y.outcome.speedup.to_bits());
            assert_eq!(x.baseline_s.to_bits(), y.baseline_s.to_bits());
        }
    }

    #[test]
    fn iterations_improve_correctness() {
        let suite = Suite::sample(6);
        let one = run_campaign(&suite, None, &small_cfg("cuda", 1));
        let five = run_campaign(&suite, None, &small_cfg("cuda", 5));
        let rate = |c: &CampaignResult| {
            let o: Vec<_> = c.results.iter().map(|r| r.outcome).collect();
            metrics::correctness_rate(&o)
        };
        assert!(rate(&five) >= rate(&one), "5-iter {} < 1-iter {}", rate(&five), rate(&one));
    }

    #[test]
    fn state_census_labels_valid() {
        let suite = Suite::sample(4);
        let c = run_campaign(&suite, None, &small_cfg("metal", 3));
        for k in c.state_census().keys() {
            assert!(matches!(
                *k,
                "generation_failure" | "compilation_failure" | "runtime_error" | "mismatch" | "correct"
            ));
        }
    }

    #[test]
    fn metal_excludes_unsupported() {
        let suite = Suite::full();
        let mut cfg = small_cfg("metal", 1);
        cfg.personas = vec![by_name("deepseek-v3").unwrap()];
        // run only L1 problems via a sample for speed
        let sample = Suite::sample(40); // 40 L1 includes some conv3dT
        let c = run_campaign(&sample, None, &cfg);
        let l1 = c
            .results
            .iter()
            .filter(|r| r.level == Level::L1)
            .count();
        let expected = sample
            .supported_on(&metal::m4_max())
            .by_level(Level::L1)
            .len();
        assert_eq!(l1, expected);
        let _ = suite;
    }
}
