//! Structural validation of candidate graphs.
//!
//! The generation agent's *syntax-class* defects corrupt the graph in
//! ways this pass genuinely detects (dangling operand ids, shape
//! inconsistencies, empty outputs).  A validation failure maps to the
//! paper's **compilation failure** execution state (§3.3) — the message
//! becomes the "compiler error" fed back on the next refinement
//! iteration.

use super::graph::{infer_shape, Graph};
use super::op::Op;
use anyhow::{bail, Result};

/// Validate graph structure and types.  Returns the compiler-style
/// error message on failure.
pub fn validate(g: &Graph) -> Result<()> {
    if g.nodes.is_empty() {
        bail!("error: empty module");
    }
    if g.outputs.is_empty() {
        bail!("error: module has no outputs");
    }
    let mut seen_inputs = vec![false; g.input_shapes.len()];
    for (id, node) in g.nodes.iter().enumerate() {
        // topological discipline: operands strictly precede users
        for o in node.op.operands() {
            if o >= id {
                bail!("error: node %{id} ({}) references undefined value %{o}", node.op.mnemonic());
            }
        }
        if let Op::Input { idx } = node.op {
            if idx >= g.input_shapes.len() {
                bail!("error: node %{id} reads undeclared input {idx}");
            }
            seen_inputs[idx] = true;
        }
        // re-run inference and check the recorded shape agrees
        let inferred = infer_shape(&node.op, &|i| g.nodes[i].shape.clone(), &g.input_shapes)
            .map_err(|e| anyhow::anyhow!("error: node %{id} ({}): {e}", node.op.mnemonic()))?;
        if inferred != node.shape {
            bail!(
                "error: node %{id} ({}) annotated {} but infers {}",
                node.op.mnemonic(),
                node.shape,
                inferred
            );
        }
    }
    for &o in &g.outputs {
        if o >= g.nodes.len() {
            bail!("error: output references undefined value %{o}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::{GraphBuilder, Node};
    use crate::kir::op::{BinaryKind, Op};
    use crate::tensor::Shape;

    fn good() -> Graph {
        let mut b = GraphBuilder::new("ok");
        let x = b.input(Shape::of(&[2, 2]));
        let y = b.input(Shape::of(&[2, 2]));
        let z = b.binary(BinaryKind::Add, x, y);
        b.finish(vec![z])
    }

    #[test]
    fn accepts_valid_graph() {
        assert!(validate(&good()).is_ok());
    }

    #[test]
    fn rejects_forward_reference() {
        let mut g = good();
        g.nodes[2].op = Op::Binary { kind: BinaryKind::Add, lhs: 0, rhs: 5 };
        let err = validate(&g).unwrap_err().to_string();
        assert!(err.contains("undefined value"), "{err}");
    }

    #[test]
    fn rejects_shape_annotation_mismatch() {
        let mut g = good();
        g.nodes[2].shape = Shape::of(&[3, 3]);
        let err = validate(&g).unwrap_err().to_string();
        assert!(err.contains("infers"), "{err}");
    }

    #[test]
    fn rejects_bad_output_id() {
        let mut g = good();
        g.outputs = vec![99];
        assert!(validate(&g).is_err());
    }

    #[test]
    fn rejects_type_error_inside() {
        let mut g = good();
        // overwrite add with an ill-typed matmul (2x2 @ 2x2 is fine; use reduce with bad axis)
        g.nodes[2] = Node {
            op: Op::Reduce { kind: crate::kir::op::ReduceKind::Sum, axis: 7, input: 0 },
            shape: Shape::of(&[2, 2]),
        };
        assert!(validate(&g).is_err());
    }

    #[test]
    fn rejects_empty_outputs() {
        let mut g = good();
        g.outputs.clear();
        assert!(validate(&g).is_err());
    }
}
