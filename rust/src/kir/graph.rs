//! KIR graphs: append-only node lists in topological order, with eager
//! shape inference at construction (the builder rejects ill-typed ops,
//! mirroring what a kernel compiler's frontend would reject).

use super::op::{BinaryKind, Op, ReduceKind, UnaryKind};
use crate::tensor::Shape;
use anyhow::{bail, Context, Result};

pub use super::op::NodeId;

/// One graph node: the op plus its inferred output shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub op: Op,
    pub shape: Shape,
}

/// A KIR graph.  `nodes` is topologically ordered by construction
/// (every operand id precedes its user).
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Shapes of the declared inputs, in input-index order.
    pub input_shapes: Vec<Shape>,
    pub outputs: Vec<NodeId>,
}

impl Graph {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Ids of nodes that are `Op::Input`.
    pub fn input_nodes(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.op, Op::Input { .. }))
            .map(|(i, _)| i)
            .collect()
    }

    /// Use counts per node (how many ops read it + output uses).
    pub fn use_counts(&self) -> Vec<usize> {
        let mut uses = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for o in n.op.operands() {
                uses[o] += 1;
            }
        }
        for &o in &self.outputs {
            uses[o] += 1;
        }
        uses
    }

    /// Total FLOPs of the graph (cost-model helper; see perfsim for the
    /// per-op accounting used by the simulator).
    pub fn total_flops(&self) -> f64 {
        self.nodes.iter().map(|n| node_flops(self, n)).sum()
    }

    /// Pretty print for logs and "generated program" listings.
    pub fn render(&self) -> String {
        let mut out = format!("graph {} {{\n", self.name);
        for (i, n) in self.nodes.iter().enumerate() {
            let args = n
                .op
                .operands()
                .iter()
                .map(|o| format!("%{o}"))
                .collect::<Vec<_>>()
                .join(", ");
            out.push_str(&format!(
                "  %{i}: {} = {}({args})\n",
                n.shape,
                n.op.mnemonic()
            ));
        }
        out.push_str(&format!(
            "  return {}\n}}\n",
            self.outputs
                .iter()
                .map(|o| format!("%{o}"))
                .collect::<Vec<_>>()
                .join(", ")
        ));
        out
    }
}

/// FLOPs attributed to a single node (2·M·N·K for matmul family, ~1/el
/// for elementwise, ~5/el for transcendental).
pub fn node_flops(g: &Graph, n: &Node) -> f64 {
    match &n.op {
        Op::Matmul { lhs, .. } => {
            let k = g.node(*lhs).shape.dim(1) as f64;
            2.0 * n.shape.numel() as f64 * k
        }
        Op::Conv2d { weight, .. } => {
            let w = &g.node(*weight).shape;
            2.0 * n.shape.numel() as f64 * (w.dim(1) * w.dim(2) * w.dim(3)) as f64
        }
        Op::DepthwiseConv2d { weight, .. } => {
            let w = &g.node(*weight).shape;
            2.0 * n.shape.numel() as f64 * (w.dim(2) * w.dim(3)) as f64
        }
        Op::Attention { q, k, .. } => {
            let s = g.node(*q).shape.dim(0) as f64;
            let d = g.node(*q).shape.dim(1) as f64;
            let sk = g.node(*k).shape.dim(0) as f64;
            2.0 * s * sk * d * 2.0 + 5.0 * s * sk
        }
        Op::Unary { kind, .. } if kind.is_transcendental() => 5.0 * n.shape.numel() as f64,
        Op::Softmax { .. } | Op::Layernorm { .. } => 8.0 * n.shape.numel() as f64,
        Op::Input { .. } | Op::ConstFill { .. } | Op::Reshape { .. } => 0.0,
        _ => n.shape.numel() as f64,
    }
}

/// Shape inference for one op against already-typed operands.
pub fn infer_shape(op: &Op, get: &dyn Fn(NodeId) -> Shape, input_shapes: &[Shape]) -> Result<Shape> {
    Ok(match op {
        Op::Input { idx } => input_shapes
            .get(*idx)
            .cloned()
            .with_context(|| format!("input index {idx} out of range"))?,
        Op::ConstFill { shape, .. } => shape.clone(),
        Op::Unary { input, .. } => get(*input),
        Op::Binary { lhs, rhs, .. } => {
            let (a, b) = (get(*lhs), get(*rhs));
            a.broadcast(&b)
                .with_context(|| format!("cannot broadcast {a} with {b}"))?
        }
        Op::Matmul { lhs, rhs } => {
            let (a, b) = (get(*lhs), get(*rhs));
            if a.rank() != 2 || b.rank() != 2 {
                bail!("matmul needs rank-2 operands, got {a} @ {b}");
            }
            if a.dim(1) != b.dim(0) {
                bail!("matmul inner dim mismatch: {a} @ {b}");
            }
            Shape::of(&[a.dim(0), b.dim(1)])
        }
        Op::Transpose2 { input } => {
            let s = get(*input);
            if s.rank() != 2 {
                bail!("transpose2 needs rank 2, got {s}");
            }
            Shape::of(&[s.dim(1), s.dim(0)])
        }
        Op::Reduce { axis, input, .. } => {
            let s = get(*input);
            if *axis >= s.rank() {
                bail!("reduce axis {axis} out of range for {s}");
            }
            let mut d = s.dims().to_vec();
            d[*axis] = 1;
            Shape(d)
        }
        Op::Softmax { input } => {
            let s = get(*input);
            if s.rank() < 1 {
                bail!("softmax needs rank >= 1");
            }
            s
        }
        Op::Layernorm { input, gamma, beta } => {
            let s = get(*input);
            let f = s.dim(s.rank() - 1);
            for (nm, g) in [("gamma", get(*gamma)), ("beta", get(*beta))] {
                if g.rank() != 1 || g.dim(0) != f {
                    bail!("layernorm {nm} shape {g} != [{f}]");
                }
            }
            s
        }
        Op::Attention { q, k, v } => {
            let (qs, ks, vs) = (get(*q), get(*k), get(*v));
            if qs.rank() != 2 || ks.rank() != 2 || vs.rank() != 2 {
                bail!("attention needs rank-2 q/k/v");
            }
            if qs.dim(1) != ks.dim(1) || ks.dim(0) != vs.dim(0) {
                bail!("attention shape mismatch q={qs} k={ks} v={vs}");
            }
            Shape::of(&[qs.dim(0), vs.dim(1)])
        }
        Op::Conv2d { input, weight, stride, padding } => {
            let (x, w) = (get(*input), get(*weight));
            if x.rank() != 4 || w.rank() != 4 {
                bail!("conv2d needs rank-4 input/weight");
            }
            if x.dim(1) != w.dim(1) {
                bail!("conv2d channel mismatch: {x} vs {w}");
            }
            conv_out_shape(&x, w.dim(0), w.dim(2), w.dim(3), *stride, *padding)?
        }
        Op::DepthwiseConv2d { input, weight, stride, padding } => {
            let (x, w) = (get(*input), get(*weight));
            if x.rank() != 4 || w.rank() != 4 || w.dim(1) != 1 {
                bail!("dwconv2d needs rank-4, weight [C,1,kh,kw]");
            }
            if x.dim(1) != w.dim(0) {
                bail!("dwconv2d channel mismatch: {x} vs {w}");
            }
            conv_out_shape(&x, x.dim(1), w.dim(2), w.dim(3), *stride, *padding)?
        }
        Op::MaxPool2d { input, k, stride } | Op::AvgPool2d { input, k, stride } => {
            let x = get(*input);
            if x.rank() != 4 {
                bail!("pool2d needs rank 4");
            }
            if *k > x.dim(2) || *k > x.dim(3) {
                bail!("pool window {k} exceeds spatial dims of {x}");
            }
            Shape::of(&[
                x.dim(0),
                x.dim(1),
                (x.dim(2) - k) / stride + 1,
                (x.dim(3) - k) / stride + 1,
            ])
        }
        Op::GlobalAvgPool { input } => {
            let x = get(*input);
            if x.rank() != 4 {
                bail!("gavgpool needs rank 4");
            }
            Shape::of(&[x.dim(0), x.dim(1), 1, 1])
        }
        Op::Concat { inputs, axis } => {
            if inputs.is_empty() {
                bail!("concat of nothing");
            }
            let first = get(inputs[0]);
            if *axis >= first.rank() {
                bail!("concat axis {axis} out of range");
            }
            let mut total = 0;
            for &i in inputs {
                let s = get(i);
                if s.rank() != first.rank() {
                    bail!("concat rank mismatch");
                }
                for d in 0..s.rank() {
                    if d != *axis && s.dim(d) != first.dim(d) {
                        bail!("concat dim {d} mismatch: {s} vs {first}");
                    }
                }
                total += s.dim(*axis);
            }
            let mut dims = first.dims().to_vec();
            dims[*axis] = total;
            Shape(dims)
        }
        Op::Reshape { input, shape } => {
            let s = get(*input);
            if s.numel() != shape.numel() {
                bail!("reshape {s} -> {shape} changes element count");
            }
            shape.clone()
        }
    })
}

fn conv_out_shape(x: &Shape, out_c: usize, kh: usize, kw: usize, stride: usize, padding: usize) -> Result<Shape> {
    let h = x.dim(2) + 2 * padding;
    let w = x.dim(3) + 2 * padding;
    if kh > h || kw > w || stride == 0 {
        bail!("conv kernel {kh}x{kw} stride {stride} invalid for {x}");
    }
    Ok(Shape::of(&[
        x.dim(0),
        out_c,
        (h - kh) / stride + 1,
        (w - kw) / stride + 1,
    ]))
}

/// Builder with eager shape inference.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    name: String,
    nodes: Vec<Node>,
    input_shapes: Vec<Shape>,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder {
            name: name.to_string(),
            nodes: Vec::new(),
            input_shapes: Vec::new(),
        }
    }

    /// Declare the next graph input.
    pub fn input(&mut self, shape: Shape) -> NodeId {
        let idx = self.input_shapes.len();
        self.input_shapes.push(shape.clone());
        self.nodes.push(Node {
            op: Op::Input { idx },
            shape,
        });
        self.nodes.len() - 1
    }

    /// Push any op with inference; panics on type errors (builder misuse
    /// is a bug in *our* workload definitions, not a synthesis defect).
    pub fn push(&mut self, op: Op) -> NodeId {
        let nodes = &self.nodes;
        let shape = infer_shape(&op, &|i| nodes[i].shape.clone(), &self.input_shapes)
            .unwrap_or_else(|e| panic!("builder type error on {op:?}: {e}"));
        self.nodes.push(Node { op, shape });
        self.nodes.len() - 1
    }

    pub fn unary(&mut self, kind: UnaryKind, input: NodeId) -> NodeId {
        self.push(Op::Unary { kind, input })
    }

    pub fn binary(&mut self, kind: BinaryKind, lhs: NodeId, rhs: NodeId) -> NodeId {
        self.push(Op::Binary { kind, lhs, rhs })
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(BinaryKind::Add, a, b)
    }

    pub fn matmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(Op::Matmul { lhs: a, rhs: b })
    }

    pub fn reduce(&mut self, kind: ReduceKind, axis: usize, input: NodeId) -> NodeId {
        self.push(Op::Reduce { kind, axis, input })
    }

    pub fn conv2d(&mut self, input: NodeId, weight: NodeId, stride: usize, padding: usize) -> NodeId {
        self.push(Op::Conv2d { input, weight, stride, padding })
    }

    pub fn finish(self, outputs: Vec<NodeId>) -> Graph {
        assert!(!outputs.is_empty(), "graph must have outputs");
        for &o in &outputs {
            assert!(o < self.nodes.len(), "output id {o} out of range");
        }
        Graph {
            name: self.name,
            nodes: self.nodes,
            input_shapes: self.input_shapes,
            outputs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_graph() -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input(Shape::of(&[4, 8]));
        let w = b.input(Shape::of(&[8, 2]));
        let m = b.matmul(x, w);
        let r = b.unary(UnaryKind::Relu, m);
        b.finish(vec![r])
    }

    #[test]
    fn builder_infers_shapes() {
        let g = simple_graph();
        assert_eq!(g.node(2).shape, Shape::of(&[4, 2]));
        assert_eq!(g.node(3).shape, Shape::of(&[4, 2]));
        assert_eq!(g.input_shapes.len(), 2);
    }

    #[test]
    #[should_panic]
    fn builder_rejects_bad_matmul() {
        let mut b = GraphBuilder::new("bad");
        let x = b.input(Shape::of(&[4, 8]));
        let y = b.input(Shape::of(&[4, 8]));
        b.matmul(x, y);
    }

    #[test]
    fn infer_errors_are_reported_not_panicked() {
        // direct infer_shape calls (what validation uses) return Err
        let shapes = [Shape::of(&[2, 3]), Shape::of(&[5, 7])];
        let op = Op::Matmul { lhs: 0, rhs: 1 };
        let r = infer_shape(&op, &|i| shapes[i].clone(), &[]);
        assert!(r.is_err());
    }

    #[test]
    fn render_mentions_ops() {
        let g = simple_graph();
        let s = g.render();
        assert!(s.contains("matmul") && s.contains("relu") && s.contains("return"));
    }

    #[test]
    fn use_counts() {
        let g = simple_graph();
        let uses = g.use_counts();
        assert_eq!(uses[0], 1); // x read by matmul
        assert_eq!(uses[2], 1); // matmul read by relu
        assert_eq!(uses[3], 1); // relu is output
    }

    #[test]
    fn flops_positive_for_matmul() {
        let g = simple_graph();
        // 2*4*2*8 = 128 matmul flops + 8 relu flops
        assert!(g.total_flops() >= 128.0);
    }

    #[test]
    fn conv_shape_inference() {
        let mut b = GraphBuilder::new("c");
        let x = b.input(Shape::of(&[1, 3, 8, 8]));
        let w = b.input(Shape::of(&[16, 3, 3, 3]));
        let y = b.conv2d(x, w, 1, 1);
        let g = b.finish(vec![y]);
        assert_eq!(g.node(y).shape, Shape::of(&[1, 16, 8, 8]));
    }
}
