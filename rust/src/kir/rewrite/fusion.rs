//! Fusion-group discovery.
//!
//! Fusion is a *schedule* decision, not a graph edit: the lowered plan
//! partitions nodes into fusion groups, each becoming one kernel launch
//! (one HBM round trip for the group's interior).  This module computes
//! the groups a synthesizer of a given skill would find:
//!
//! - `greedy_epilogue` — attach elementwise chains to their compute
//!   anchor (matmul/conv epilogues) and merge pure elementwise chains;
//!   this is what torch.compile's Inductor-style baseline does, and
//!   what strong models discover (§5.1: "optimizations like kernel
//!   fusion").
//! - `none` — one kernel per op: the PyTorch-eager analog.
//! - `partial(k)` — only the first k opportunities, modelling weaker
//!   synthesizers.

use crate::kir::graph::{Graph, NodeId};
use crate::kir::op::Op;
use crate::kir::patch::DirtySet;

/// A fusion plan: `group[i]` is the group index of node i.  Nodes that
/// produce no kernel (inputs, reshapes, constants) carry `usize::MAX`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusionPlan {
    pub group: Vec<usize>,
    pub n_groups: usize,
}

impl FusionPlan {
    /// Node ids per group, in topological order.
    pub fn members(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.n_groups];
        for (id, &grp) in self.group.iter().enumerate() {
            if grp != usize::MAX {
                out[grp].push(id);
            }
        }
        out
    }

    /// Number of kernel launches this plan implies.
    pub fn launches(&self) -> usize {
        self.n_groups
    }
}

/// Does this node emit work at all (kernels), or is it free?
pub fn emits_kernel(op: &Op) -> bool {
    !matches!(op, Op::Input { .. } | Op::ConstFill { .. } | Op::Reshape { .. })
}

/// One kernel per op — the eager-mode plan.
pub fn none(g: &Graph) -> FusionPlan {
    let mut group = vec![usize::MAX; g.nodes.len()];
    let mut n = 0;
    for (id, node) in g.nodes.iter().enumerate() {
        if emits_kernel(&node.op) {
            group[id] = n;
            n += 1;
        }
    }
    FusionPlan { group, n_groups: n }
}

/// Greedy epilogue + elementwise-chain fusion.
///
/// A node joins its producer's group when:
/// - it is elementwise, and
/// - exactly one of its operands emits a kernel (the producer), and
/// - the producer's output is used only by this node (single-consumer:
///   fusing a multi-consumer producer would duplicate work), and
/// - the producer's group doesn't already contain a second compute
///   anchor (one matmul per kernel).
///
/// Reductions/softmax/layernorm may *start* a group but not join one
/// (they need the whole row — matches the Pallas kernels, where the
/// matmul epilogue is elementwise-only).
pub fn greedy_epilogue(g: &Graph) -> FusionPlan {
    let uses = g.use_counts();
    let mut group = vec![usize::MAX; g.nodes.len()];
    let n_groups = greedy_scan(g, &uses, &mut group, 0, 0);
    FusionPlan { group, n_groups }
}

/// The greedy join scan from node `start` onward, with `group[..start]`
/// and `n_groups` already settled.  Shared by the full plan and the
/// incremental refresh so the join rule cannot drift between them.
fn greedy_scan(
    g: &Graph,
    uses: &[usize],
    group: &mut [usize],
    start: usize,
    mut n_groups: usize,
) -> usize {
    for (id, node) in g.nodes.iter().enumerate().skip(start) {
        if !emits_kernel(&node.op) {
            continue;
        }
        let mut joined = None;
        if node.op.is_elementwise() {
            // candidate producers: operands that emit kernels
            let producers: Vec<NodeId> = node
                .op
                .operands()
                .into_iter()
                .filter(|&o| group[o] != usize::MAX)
                .collect();
            if producers.len() == 1 {
                let p = producers[0];
                let output_escapes = g.outputs.contains(&p);
                if uses[p] == 1 && !output_escapes {
                    joined = Some(group[p]);
                }
            }
        }
        match joined {
            Some(grp) => group[id] = grp,
            None => {
                group[id] = n_groups;
                n_groups += 1;
            }
        }
    }
    n_groups
}

/// Incrementally refresh a greedy-epilogue plan after a patch: the
/// *identity prefix* — leading new ids that are clean and kept their
/// base id — reuses the previous plan's assignments verbatim (clean
/// guarantees the join rule's every input — content, operand ids, user
/// multiset, output membership — is unchanged there), and the scan
/// resumes at the first changed id.  Falls back to a full recompute
/// when nothing is reusable.  Differentially tested bit-identical to
/// [`greedy_epilogue`] on the patched graph.
pub fn greedy_refresh(g: &Graph, prev: &FusionPlan, dirty: &DirtySet) -> FusionPlan {
    let n = g.nodes.len();
    if dirty.len() != n {
        return greedy_epilogue(g); // dirty set is for some other graph
    }
    let mut k = 0;
    while k < n
        && !dirty.is_dirty(k)
        && dirty.old_to_new.get(k).copied() == Some(Some(k))
    {
        k += 1;
    }
    if k == 0 || prev.group.len() < k {
        return greedy_epilogue(g);
    }
    let uses = g.use_counts();
    let mut group = vec![usize::MAX; n];
    group[..k].copy_from_slice(&prev.group[..k]);
    // groups are numbered in scan order, so the prefix's group indices
    // are exactly 0..n0
    let n0 = prev.group[..k]
        .iter()
        .filter(|&&grp| grp != usize::MAX)
        .map(|&grp| grp + 1)
        .max()
        .unwrap_or(0);
    let n_groups = greedy_scan(g, &uses, &mut group, k, n0);
    FusionPlan { group, n_groups }
}

/// Apply only the first `k` fusion opportunities of the greedy plan —
/// a partially-skilled synthesizer.
pub fn partial(g: &Graph, k: usize) -> FusionPlan {
    let full = greedy_epilogue(g);
    let eager = none(g);
    if k == usize::MAX {
        return full;
    }
    // an "opportunity" is a node fused into an earlier group in `full`
    // (i.e. its group differs from what a fresh group would be).
    let mut taken = 0usize;
    let mut group = vec![usize::MAX; g.nodes.len()];
    let mut n_groups = 0usize;
    let mut full_to_new: Vec<Option<usize>> = vec![None; full.n_groups];
    for (id, node) in g.nodes.iter().enumerate() {
        if !emits_kernel(&node.op) {
            continue;
        }
        let fused_in_full = {
            // fused iff an earlier node shares its full-group
            (0..id).any(|j| full.group[j] == full.group[id] && full.group[id] != usize::MAX)
        };
        if fused_in_full && taken < k {
            // join the group its full-plan leader was assigned
            let leader_new = full_to_new[full.group[id]].expect("leader first");
            group[id] = leader_new;
            taken += 1;
        } else {
            group[id] = n_groups;
            if !fused_in_full {
                full_to_new[full.group[id]] = Some(n_groups);
            }
            n_groups += 1;
        }
    }
    let _ = eager;
    FusionPlan { group, n_groups }
}

/// Count of fusion opportunities in the graph (how many launches the
/// greedy plan saves over eager).
pub fn opportunity_count(g: &Graph) -> usize {
    none(g).n_groups - greedy_epilogue(g).n_groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::{BinaryKind, UnaryKind};
    use crate::tensor::Shape;

    fn gemm_bias_relu() -> Graph {
        let mut b = GraphBuilder::new("gbr");
        let x = b.input(Shape::of(&[8, 16]));
        let w = b.input(Shape::of(&[16, 8]));
        let bias = b.input(Shape::of(&[8]));
        let m = b.matmul(x, w);
        let a = b.add(m, bias);
        let r = b.unary(UnaryKind::Relu, a);
        b.finish(vec![r])
    }

    #[test]
    fn eager_one_kernel_per_op() {
        let g = gemm_bias_relu();
        assert_eq!(none(&g).launches(), 3); // matmul, add, relu
    }

    #[test]
    fn greedy_fuses_epilogue() {
        let g = gemm_bias_relu();
        let p = greedy_epilogue(&g);
        assert_eq!(p.launches(), 1, "{:?}", p.members());
    }

    #[test]
    fn multi_consumer_blocks_fusion() {
        let mut b = GraphBuilder::new("mc");
        let x = b.input(Shape::of(&[8, 16]));
        let w = b.input(Shape::of(&[16, 8]));
        let m = b.matmul(x, w);
        let r1 = b.unary(UnaryKind::Relu, m);
        let r2 = b.unary(UnaryKind::Sigmoid, m);
        let s = b.binary(BinaryKind::Add, r1, r2);
        let g = b.finish(vec![s]);
        let p = greedy_epilogue(&g);
        // matmul used twice: relu/sigmoid cannot fold in; add has two
        // kernel-emitting operands so it can't fuse either.
        assert_eq!(p.launches(), 4);
    }

    #[test]
    fn partial_interpolates() {
        let g = gemm_bias_relu();
        assert_eq!(partial(&g, 0).launches(), 3);
        assert_eq!(partial(&g, 1).launches(), 2);
        assert_eq!(partial(&g, 2).launches(), 1);
        assert_eq!(partial(&g, usize::MAX).launches(), 1);
    }

    #[test]
    fn opportunity_count_counts() {
        assert_eq!(opportunity_count(&gemm_bias_relu()), 2);
    }

    #[test]
    fn elementwise_chain_fuses() {
        let mut b = GraphBuilder::new("chain");
        let x = b.input(Shape::of(&[128]));
        let a = b.unary(UnaryKind::Swish, x);
        let c = b.unary(UnaryKind::Relu, a);
        let d = b.unary(UnaryKind::Tanh, c);
        let g = b.finish(vec![d]);
        assert_eq!(greedy_epilogue(&g).launches(), 1);
    }

    #[test]
    fn greedy_refresh_matches_full_recompute() {
        use crate::kir::patch::GraphPatch;
        let mut b = GraphBuilder::new("rf");
        let x = b.input(Shape::of(&[64, 64]));
        let w = b.input(Shape::of(&[64, 64]));
        let m = b.matmul(x, w);
        let a = b.unary(UnaryKind::Relu, m);
        let t = b.unary(UnaryKind::Tanh, a);
        let g = b.finish(vec![t]);
        let prev = greedy_epilogue(&g);
        let mut p = GraphPatch::new(&g);
        p.prune();
        p.redirect(a, m).unwrap(); // bypass the relu
        let (g2, dirty) = p.apply().unwrap();
        assert_eq!(greedy_refresh(&g2, &prev, &dirty), greedy_epilogue(&g2));
        // identity patch: full prefix reuse is still the full plan
        let (g3, clean) = GraphPatch::new(&g).apply().unwrap();
        assert_eq!(greedy_refresh(&g3, &prev, &clean), greedy_epilogue(&g3));
        let _ = (x, w, t);
    }

    #[test]
    fn graph_output_producer_not_fused() {
        // if the intermediate is itself a graph output it must stay
        let mut b = GraphBuilder::new("esc");
        let x = b.input(Shape::of(&[16]));
        let a = b.unary(UnaryKind::Swish, x);
        let c = b.unary(UnaryKind::Relu, a);
        let g = b.finish(vec![a, c]);
        assert_eq!(greedy_epilogue(&g).launches(), 2);
    }
}
