//! Algebraic graph reduction — the §7.4 case study.
//!
//! The paper's L2-problem-12 chain
//! `linear → sum(dim=1) → max → mean → logsumexp → logsumexp`
//! collapses: `sum₁(x·W + b) = x · sum₁(W) + sum(b)` turns the
//! matrix-*matrix* product into a matrix-*vector* product (a cuBLAS
//! `gemv` in the paper, the (m,k)×(k,1) tiled matmul in our L1 kernel).
//!
//! This pass implements the distributivity rewrite
//! `Reduce(Sum, 1, Matmul(x, W))        → Matmul(x, Reduce(Sum, 1, W))`
//! `Reduce(Sum, 1, Add(Matmul(x,W), b)) → Matmul(x, RS(W)) + RS(b)`
//! (bias broadcast along rows sums to `n · …` handled per-shape).

use crate::kir::graph::{infer_shape, Graph, Node, NodeId};
use crate::kir::op::{BinaryKind, Op, ReduceKind};
use crate::kir::patch::GraphPatch;
use crate::tensor::Shape;

/// Stage the next single reduce∘matmul collapse as a patch, if any
/// match exists.  The patch appends the replacement chain (`w_sum`,
/// `mv`, optional `b_sum`/`Add`), redirects the matched Reduce to it,
/// and re-sorts + prunes on apply — one `apply_match` + DCE step of the
/// wholesale pass, bit for bit.
pub fn next_patch(g: &Graph) -> Option<GraphPatch<'_>> {
    let m = find_match(g)?;
    let Op::Matmul { lhs: x, rhs: w } = g.nodes[m.matmul_id].op else {
        unreachable!()
    };
    let mut p = GraphPatch::new(g);
    p.prune();
    p.resort();
    // w_sum = Reduce(Sum, 1, W): [k, n] -> [k, 1]
    let w_sum = p.add(Op::Reduce { kind: ReduceKind::Sum, axis: 1, input: w }).expect("rewrite types");
    // x @ w_sum : [m, 1]
    let mv = p.add(Op::Matmul { lhs: x, rhs: w_sum }).expect("rewrite types");
    let replacement = match m.add_bias {
        None => mv,
        Some((_add, bias)) => {
            // bias_sum = sum over the last axis of the bias
            let axis = g.nodes[bias].shape.rank() - 1;
            let b_sum = p
                .add(Op::Reduce { kind: ReduceKind::Sum, axis, input: bias })
                .expect("rewrite types");
            p.add(Op::Binary { kind: BinaryKind::Add, lhs: mv, rhs: b_sum }).expect("rewrite types")
        }
    };
    p.redirect(m.reduce_id, replacement).expect("replacement keeps the reduce's shape");
    Some(p)
}

/// Apply the matmul-chain reductions everywhere they match.
/// Patch-based: applies [`next_patch`] to a fixpoint; requires a
/// structurally valid graph.
pub fn reduce_matmul_chains(g: &Graph) -> Graph {
    let mut g = g.clone();
    loop {
        let next = match next_patch(&g) {
            Some(p) => p.apply().expect("algebraic patch applies to a structurally valid graph").0,
            None => break,
        };
        g = next;
    }
    super::dce(&g)
}

/// The original clone-and-rebuild reduction loop, kept as the
/// differential reference for the patch-vs-whole harness.
pub fn reduce_matmul_chains_wholesale(g: &Graph) -> Graph {
    let mut g = g.clone();
    loop {
        match find_match(&g) {
            // DCE after every application: the matched Reduce node is
            // dead-but-present after redirect, and without removal
            // find_match would rediscover it forever.
            Some(m) => g = super::dce_wholesale(&apply_match(&g, m)),
            None => break,
        }
    }
    super::dce_wholesale(&g)
}

/// Count how many reduction opportunities exist (harness statistic).
pub fn count_opportunities(g: &Graph) -> usize {
    let mut n = 0;
    let mut cur = g.clone();
    loop {
        let next = match next_patch(&cur) {
            Some(p) => p.apply().expect("algebraic patch applies to a structurally valid graph").0,
            None => break,
        };
        cur = next;
        n += 1;
    }
    n
}

#[derive(Debug, Clone, Copy)]
struct Match {
    /// The Reduce(Sum, axis=1) node to rewrite.
    reduce_id: NodeId,
    /// Matmul feeding it.
    matmul_id: NodeId,
    /// Optional Add between them (bias).
    add_bias: Option<(NodeId, NodeId)>, // (add node, bias operand)
}

fn find_match(g: &Graph) -> Option<Match> {
    for (id, n) in g.nodes.iter().enumerate() {
        let Op::Reduce { kind: ReduceKind::Sum, axis: 1, input } = n.op else {
            continue;
        };
        match &g.nodes[input].op {
            Op::Matmul { .. } if g.nodes[input].shape.rank() == 2 => {
                return Some(Match { reduce_id: id, matmul_id: input, add_bias: None });
            }
            Op::Binary { kind: BinaryKind::Add, lhs, rhs } => {
                // Add(Matmul, bias) where bias broadcasts along rows
                let (mm, bias) = if matches!(g.nodes[*lhs].op, Op::Matmul { .. }) {
                    (*lhs, *rhs)
                } else if matches!(g.nodes[*rhs].op, Op::Matmul { .. }) {
                    (*rhs, *lhs)
                } else {
                    continue;
                };
                let bs = &g.nodes[bias].shape;
                // bias [n] or [1,n]: each row sums the same total
                let mm_n = g.nodes[mm].shape.dim(1);
                let ok = (bs.rank() == 1 && bs.dim(0) == mm_n)
                    || (bs.rank() == 2 && bs.dim(0) == 1 && bs.dim(1) == mm_n);
                if ok {
                    return Some(Match {
                        reduce_id: id,
                        matmul_id: mm,
                        add_bias: Some((input, bias)),
                    });
                }
            }
            _ => {}
        }
    }
    None
}

fn apply_match(g: &Graph, m: Match) -> Graph {
    let Op::Matmul { lhs: x, rhs: w } = g.nodes[m.matmul_id].op else {
        unreachable!()
    };
    let mut nodes = g.nodes.clone();
    let push = |nodes: &mut Vec<Node>, op: Op, input_shapes: &[Shape]| -> NodeId {
        let shape = {
            let nn = &*nodes;
            infer_shape(&op, &|i| nn[i].shape.clone(), input_shapes).expect("rewrite types")
        };
        nodes.push(Node { op, shape });
        nodes.len() - 1
    };
    // w_sum = Reduce(Sum, 1, W): [k, n] -> [k, 1]
    let w_sum = push(
        &mut nodes,
        Op::Reduce { kind: ReduceKind::Sum, axis: 1, input: w },
        &g.input_shapes,
    );
    // x @ w_sum : [m, 1]
    let mv = push(&mut nodes, Op::Matmul { lhs: x, rhs: w_sum }, &g.input_shapes);
    let replacement = match m.add_bias {
        None => mv,
        Some((_add, bias)) => {
            // bias_sum = sum over the last axis of the bias
            let axis = g.nodes[bias].shape.rank() - 1;
            let b_sum = push(
                &mut nodes,
                Op::Reduce { kind: ReduceKind::Sum, axis, input: bias },
                &g.input_shapes,
            );
            push(
                &mut nodes,
                Op::Binary { kind: BinaryKind::Add, lhs: mv, rhs: b_sum },
                &g.input_shapes,
            )
        }
    };
    // All users of reduce_id now read `replacement`.  The new nodes are
    // appended after every existing node, which breaks the topological
    // invariant for users of reduce_id that appear before the tail — so
    // rebuild in topological order via a full remap: since users of
    // reduce_id strictly follow it, and replacement > any user, we must
    // re-sort.  Simplest correct approach: move the graph through an
    // explicit reindexing that orders `nodes` topologically.
    let mut gg = Graph {
        name: g.name.clone(),
        nodes,
        input_shapes: g.input_shapes.clone(),
        outputs: g.outputs.clone(),
    };
    redirect(&mut gg, m.reduce_id, replacement);
    toposort(&gg)
}

/// Redirect every use of `from` to `to`.
fn redirect(g: &mut Graph, from: NodeId, to: NodeId) {
    for n in g.nodes.iter_mut() {
        n.op = n.op.map_operands(|o| if o == from { to } else { o });
    }
    // the replacement's own definition must not be self-referential;
    // rebuild its operand list unmapped (it reads x/w/bias directly).
    for o in g.outputs.iter_mut() {
        if *o == from {
            *o = to;
        }
    }
}

/// Kahn re-sort into a valid topological node order.
fn toposort(g: &Graph) -> Graph {
    let n = g.nodes.len();
    let mut indeg = vec![0usize; n];
    let mut users: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for (id, node) in g.nodes.iter().enumerate() {
        let mut ops = node.op.operands();
        ops.sort_unstable();
        ops.dedup();
        indeg[id] = ops.len();
        for o in ops {
            users[o].push(id);
        }
    }
    let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
    queue.sort_unstable();
    let mut order = Vec::with_capacity(n);
    let mut qi = 0;
    while qi < queue.len() {
        let id = queue[qi];
        qi += 1;
        order.push(id);
        for &u in &users[id] {
            indeg[u] -= 1;
            if indeg[u] == 0 {
                queue.push(u);
            }
        }
    }
    assert_eq!(order.len(), n, "cycle introduced by rewrite");
    let mut remap = vec![0usize; n];
    for (new, &old) in order.iter().enumerate() {
        remap[old] = new;
    }
    let mut nodes = vec![
        Node {
            op: Op::Input { idx: 0 },
            shape: Shape::scalar(),
        };
        n
    ];
    for (old, node) in g.nodes.iter().enumerate() {
        nodes[remap[old]] = Node {
            op: node.op.map_operands(|o| remap[o]),
            shape: node.shape.clone(),
        };
    }
    Graph {
        name: g.name.clone(),
        nodes,
        input_shapes: g.input_shapes.clone(),
        outputs: g.outputs.iter().map(|&o| remap[o]).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::interp::eval;
    use crate::kir::op::ReduceKind;
    use crate::kir::validate::validate;
    use crate::tensor::{Shape, Tensor};
    use crate::util::rng::Pcg;

    /// The paper's L2-problem-12 chain.
    fn problem12() -> Graph {
        let mut b = GraphBuilder::new("p12");
        let x = b.input(Shape::of(&[8, 16]));
        let w = b.input(Shape::of(&[16, 32]));
        let bias = b.input(Shape::of(&[32]));
        let mm = b.matmul(x, w);
        let lin = b.add(mm, bias);
        let s = b.reduce(ReduceKind::Sum, 1, lin);
        let mx = b.reduce(ReduceKind::Max, 1, s);
        let mean = b.reduce(ReduceKind::Mean, 1, mx);
        let l1 = b.reduce(ReduceKind::LogSumExp, 1, mean);
        let l2 = b.reduce(ReduceKind::LogSumExp, 1, l1);
        b.finish(vec![l2])
    }

    fn rand_inputs(g: &Graph, seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg::seed(seed);
        g.input_shapes
            .iter()
            .map(|s| Tensor::randn(s.clone(), &mut rng, 0.5))
            .collect()
    }

    #[test]
    fn problem12_reduces_matmul_to_matvec() {
        let g = problem12();
        let r = reduce_matmul_chains(&g);
        validate(&r).expect("rewritten graph valid");
        assert_eq!(
            r,
            reduce_matmul_chains_wholesale(&g),
            "patch reduction diverges from the wholesale reference"
        );
        // the rewritten matmul must have an [k,1]-shaped rhs (matvec)
        let matvec = r.nodes.iter().any(|n| {
            matches!(&n.op, Op::Matmul { rhs, .. } if r.nodes[*rhs].shape.dims() == [16, 1])
        });
        assert!(matvec, "{}", r.render());
    }

    #[test]
    fn rewrite_preserves_semantics() {
        let g = problem12();
        let r = reduce_matmul_chains(&g);
        for seed in 0..8 {
            let ins = rand_inputs(&g, seed);
            let want = eval(&g, &ins).unwrap();
            let got = eval(&r, &ins).unwrap();
            assert_eq!(got[0].shape, want[0].shape);
            assert!(
                got[0].allclose(&want[0], 1e-3, 1e-3),
                "seed {seed}: {:?} vs {:?}",
                got[0],
                want[0]
            );
        }
    }

    #[test]
    fn plain_matmul_sum_also_reduces() {
        let mut b = GraphBuilder::new("plain");
        let x = b.input(Shape::of(&[4, 8]));
        let w = b.input(Shape::of(&[8, 6]));
        let mm = b.matmul(x, w);
        let s = b.reduce(ReduceKind::Sum, 1, mm);
        let g = b.finish(vec![s]);
        let r = reduce_matmul_chains(&g);
        validate(&r).unwrap();
        let ins = rand_inputs(&g, 3);
        assert!(eval(&r, &ins).unwrap()[0].allclose(&eval(&g, &ins).unwrap()[0], 1e-4, 1e-4));
        assert_eq!(count_opportunities(&g), 1);
    }

    #[test]
    fn no_match_is_noop_semantically() {
        let mut b = GraphBuilder::new("nomatch");
        let x = b.input(Shape::of(&[4, 8]));
        let w = b.input(Shape::of(&[8, 6]));
        let mm = b.matmul(x, w);
        let g = b.finish(vec![mm]);
        let r = reduce_matmul_chains(&g);
        let ins = rand_inputs(&g, 4);
        assert!(eval(&r, &ins).unwrap()[0].allclose(&eval(&g, &ins).unwrap()[0], 1e-6, 1e-6));
        assert_eq!(count_opportunities(&g), 0);
    }

    #[test]
    fn flops_strictly_drop() {
        let g = problem12();
        let r = reduce_matmul_chains(&g);
        assert!(
            r.total_flops() < g.total_flops() / 4.0,
            "flops {} -> {}",
            g.total_flops(),
            r.total_flops()
        );
    }
}
