//! Constant-output collapse — the §7.3 "invariance exploitation" case
//! study.
//!
//! The paper observed models recognizing that certain KernelBench
//! problems produce *constant* outputs regardless of the input (e.g.
//! GemmMaxSubtractGELU: `y - y.mean(dim=1)` over a dim-1 tensor is all
//! zeros, and `GELU(0) = 0`), then replacing the whole graph with a
//! cached constant tensor.  This pass proves constness structurally:
//!
//! 1. singleton-axis reductions are the identity (max/mean/sum over a
//!    size-1 axis);
//! 2. `sub(a, a)` is zero; `mul`-by-zero is zero;
//! 3. pointwise functions of a constant are that constant transformed;
//! 4. if a graph *output* folds to a known constant value, the output
//!    is replaced by `ConstFill` — the "ultra-fast inference model".

use crate::kir::graph::{Graph, Node, NodeId};
use crate::kir::op::{BinaryKind, Op, ReduceKind, UnaryKind};
use crate::kir::patch::GraphPatch;

/// Per-node constness lattice: either unknown or a known fill value.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Constness {
    Unknown,
    Fill(f32),
}

/// Stage constant folding as a patch:
/// 1. singleton-axis reductions become redirects to their input, and
///    `sub(a, a)` (post-redirect) becomes an in-place `ConstFill(0)`;
/// 2. the constness lattice runs over the *virtually* simplified graph
///    (base ids, staged edits resolved);
/// 3. each provably-constant output position gains a fresh `ConstFill`
///    node and an output rewire;
/// with one final prune standing in for the wholesale pass's DCEs.
pub fn patch(g: &Graph) -> GraphPatch<'_> {
    let n = g.nodes.len();
    let mut p = GraphPatch::new(g);
    p.prune();
    // 1. structural identities, in base-id space: alias[i] = the
    // canonical base node i resolves to; zeroed[i] = replaced by zero.
    let mut alias: Vec<NodeId> = (0..n).collect();
    let mut zeroed = vec![false; n];
    for id in 0..n {
        let op = g.nodes[id].op.map_operands(|o| alias[o]);
        match &op {
            // aliasing preserves shapes, so the singleton-axis check
            // reads base shapes even through alias chains
            Op::Reduce { kind, axis, input }
                if g.nodes[*input].shape.dim(*axis) == 1
                    && matches!(kind, ReduceKind::Sum | ReduceKind::Max | ReduceKind::Mean) =>
            {
                alias[id] = *input;
                p.redirect(id, *input).expect("singleton reduce aliases to a same-shaped input");
            }
            Op::Binary { kind: BinaryKind::Sub, lhs, rhs } if lhs == rhs => {
                zeroed[id] = true;
                p.replace(id, Op::ConstFill { value: 0.0, shape: g.nodes[id].shape.clone() })
                    .expect("zero fill keeps the node's shape");
            }
            _ => {}
        }
    }
    // 2. constness lattice over the virtually-simplified structure
    let mut konst = vec![Constness::Unknown; n];
    for id in 0..n {
        konst[id] = if alias[id] != id {
            konst[alias[id]]
        } else if zeroed[id] {
            Constness::Fill(0.0)
        } else {
            let op = g.nodes[id].op.map_operands(|o| alias[o]);
            constness_of(&op, &|i| g.nodes[i].shape.clone(), &konst)
        };
    }
    // 3. constant outputs collapse to ConstFill
    for (pos, &out) in g.outputs.iter().enumerate() {
        let eff = alias[out];
        if let Constness::Fill(v) = konst[eff] {
            let already = zeroed[eff] || matches!(g.nodes[eff].op, Op::ConstFill { .. });
            if !already {
                let shape = g.nodes[eff].shape.clone();
                let fill = p
                    .add(Op::ConstFill { value: v, shape })
                    .expect("const fill carries its own shape");
                p.rewire_output(pos, fill).expect("one rewire per output position");
            }
        }
    }
    p
}

/// Fold provably-constant subgraphs; collapse constant outputs to
/// `ConstFill` nodes.  Semantics-preserving by construction.
/// Patch-based; requires a structurally valid graph.
pub fn fold(g: &Graph) -> Graph {
    patch(g).apply().expect("fold patch applies to a structurally valid graph").0
}

/// The original clone-and-rebuild fold, kept as the differential
/// reference for the patch-vs-whole harness.
pub fn fold_wholesale(g: &Graph) -> Graph {
    let mut g = simplify_singleton_reduce(g);
    let mut konst = vec![Constness::Unknown; g.nodes.len()];
    for id in 0..g.nodes.len() {
        konst[id] = infer_constness(&g, id, &konst);
    }
    // Replace constant outputs with ConstFill nodes.
    let mut changed = false;
    let mut new_outputs = g.outputs.clone();
    for out in new_outputs.iter_mut() {
        if let Constness::Fill(v) = konst[*out] {
            if !matches!(g.nodes[*out].op, Op::ConstFill { .. }) {
                let shape = g.nodes[*out].shape.clone();
                g.nodes.push(Node {
                    op: Op::ConstFill { value: v, shape: shape.clone() },
                    shape,
                });
                *out = g.nodes.len() - 1;
                changed = true;
            }
        }
    }
    g.outputs = new_outputs;
    if changed {
        super::dce_wholesale(&g)
    } else {
        g
    }
}

/// Is the graph's every output a provable constant?  (Used by the
/// harness to report the §7.3 "cheating" rate.)
pub fn output_is_constant(g: &Graph) -> bool {
    let g = simplify_singleton_reduce(g);
    let mut konst = vec![Constness::Unknown; g.nodes.len()];
    for id in 0..g.nodes.len() {
        konst[id] = infer_constness(&g, id, &konst);
    }
    g.outputs.iter().all(|&o| matches!(konst[o], Constness::Fill(_)))
}

/// Rewrite `reduce(axis)` where dim(axis)==1 into the identity, and
/// `sub(a, a)` into zero — the two structural facts behind §7.3.
fn simplify_singleton_reduce(g: &Graph) -> Graph {
    let mut nodes: Vec<Node> = Vec::with_capacity(g.nodes.len());
    // alias[i] = j means node i is equivalent to node j (identity rewrite)
    let mut alias: Vec<NodeId> = (0..g.nodes.len()).collect();
    for (id, n) in g.nodes.iter().enumerate() {
        let op = n.op.map_operands(|o| alias[o]);
        let resolved = match &op {
            // NOTE: `op` operands are already remapped into the new node
            // list, so shapes must be read from `nodes`, not `g.nodes`.
            Op::Reduce { kind, axis, input }
                if nodes[*input].shape.dim(*axis) == 1
                    && matches!(kind, ReduceKind::Sum | ReduceKind::Max | ReduceKind::Mean) =>
            {
                // identity over a singleton axis: alias to the input
                alias[id] = *input;
                None
            }
            Op::Binary { kind: BinaryKind::Sub, lhs, rhs } if lhs == rhs => Some(Op::ConstFill {
                value: 0.0,
                shape: n.shape.clone(),
            }),
            _ => Some(op),
        };
        match resolved {
            Some(op) => {
                nodes.push(Node { op, shape: n.shape.clone() });
                alias[id] = nodes.len() - 1;
            }
            None => { /* aliased away; alias[id] already set */ }
        }
    }
    let out = Graph {
        name: g.name.clone(),
        nodes,
        input_shapes: g.input_shapes.clone(),
        outputs: g.outputs.iter().map(|&o| alias[o]).collect(),
    };
    super::dce_wholesale(&out)
}

fn infer_constness(g: &Graph, id: NodeId, konst: &[Constness]) -> Constness {
    constness_of(&g.nodes[id].op, &|i| g.nodes[i].shape.clone(), konst)
}

/// The constness lattice step for one op, with operand shapes supplied
/// by the caller — shared between the wholesale pass (shapes of the
/// simplified graph) and the patch pass (base shapes, which aliasing
/// preserves).
fn constness_of(
    op: &Op,
    shape_of: &dyn Fn(NodeId) -> crate::tensor::Shape,
    konst: &[Constness],
) -> Constness {
    match op {
        Op::ConstFill { value, .. } => Constness::Fill(*value),
        Op::Input { .. } => Constness::Unknown,
        Op::Unary { kind, input } => match konst[*input] {
            Constness::Fill(v) => Constness::Fill(apply_unary(*kind, v)),
            _ => Constness::Unknown,
        },
        Op::Binary { kind, lhs, rhs } => match (konst[*lhs], konst[*rhs]) {
            (Constness::Fill(a), Constness::Fill(b)) => Constness::Fill(apply_binary(*kind, a, b)),
            // mul by constant zero annihilates regardless of the other side
            (Constness::Fill(z), _) | (_, Constness::Fill(z))
                if *kind == BinaryKind::Mul && z == 0.0 =>
            {
                Constness::Fill(0.0)
            }
            _ => Constness::Unknown,
        },
        Op::Reduce { kind, input, axis } => match konst[*input] {
            Constness::Fill(v) => {
                let rdim = shape_of(*input).dim(*axis) as f32;
                Constness::Fill(match kind {
                    ReduceKind::Sum => v * rdim,
                    ReduceKind::Max | ReduceKind::Mean => v,
                    ReduceKind::LogSumExp => v + rdim.ln(),
                })
            }
            _ => Constness::Unknown,
        },
        Op::Softmax { input } => match konst[*input] {
            // softmax of a constant row is uniform 1/n
            Constness::Fill(_) => {
                let s = shape_of(*input);
                Constness::Fill(1.0 / s.dim(s.rank() - 1) as f32)
            }
            _ => Constness::Unknown,
        },
        Op::Reshape { input, .. } | Op::Transpose2 { input } | Op::GlobalAvgPool { input } => {
            konst[*input]
        }
        Op::Concat { inputs, .. } => {
            let vals: Vec<Constness> = inputs.iter().map(|&i| konst[i]).collect();
            match vals.split_first() {
                Some((Constness::Fill(v), rest))
                    if rest.iter().all(|c| *c == Constness::Fill(*v)) =>
                {
                    Constness::Fill(*v)
                }
                _ => Constness::Unknown,
            }
        }
        Op::MaxPool2d { input, .. } | Op::AvgPool2d { input, .. } => konst[*input],
        // matmul/conv of an all-c tensor is constant too, but we only
        // claim the zero case (exact regardless of the other operand)
        Op::Matmul { lhs, rhs } | Op::Conv2d { input: lhs, weight: rhs, .. } => {
            match (konst[*lhs], konst[*rhs]) {
                (Constness::Fill(z), _) | (_, Constness::Fill(z)) if z == 0.0 => {
                    Constness::Fill(0.0)
                }
                _ => Constness::Unknown,
            }
        }
        _ => Constness::Unknown,
    }
}

fn apply_unary(kind: UnaryKind, v: f32) -> f32 {
    match kind {
        UnaryKind::Relu => v.max(0.0),
        UnaryKind::Sigmoid => 1.0 / (1.0 + (-v).exp()),
        UnaryKind::Swish => v / (1.0 + (-v).exp()),
        UnaryKind::Gelu => {
            let c = 0.797_884_56_f32;
            0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
        }
        UnaryKind::Tanh => v.tanh(),
        UnaryKind::Exp => v.exp(),
        UnaryKind::Neg => -v,
        UnaryKind::Square => v * v,
        UnaryKind::Sqrt => v.sqrt(),
    }
}

fn apply_binary(kind: BinaryKind, a: f32, b: f32) -> f32 {
    match kind {
        BinaryKind::Add => a + b,
        BinaryKind::Sub => a - b,
        BinaryKind::Mul => a * b,
        BinaryKind::Div => a / b,
        BinaryKind::Max => a.max(b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::interp::eval;
    use crate::kir::op::{BinaryKind, ReduceKind, UnaryKind};
    use crate::tensor::{Shape, Tensor};
    use crate::util::rng::Pcg;

    /// GemmMaxSubtractGELU (§7.3 / appendix C.3): the chain collapses to
    /// all-zeros because mean over the already-reduced axis is identity.
    fn gemm_max_subtract_gelu() -> Graph {
        let mut b = GraphBuilder::new("gemm_max_sub_gelu");
        let x = b.input(Shape::of(&[8, 16]));
        let w = b.input(Shape::of(&[16, 24]));
        let bias = b.input(Shape::of(&[24]));
        let m = b.matmul(x, w);
        let y = b.add(m, bias);
        let mx = b.reduce(ReduceKind::Max, 1, y); // [8,1]
        let mean = b.reduce(ReduceKind::Mean, 1, mx); // identity over dim 1
        let sub = b.binary(BinaryKind::Sub, mx, mean); // zero
        let gelu = b.unary(UnaryKind::Gelu, sub); // GELU(0)=0
        b.finish(vec![gelu])
    }

    #[test]
    fn detects_constant_output() {
        assert!(output_is_constant(&gemm_max_subtract_gelu()));
    }

    #[test]
    fn folded_graph_is_tiny_and_correct() {
        let g = gemm_max_subtract_gelu();
        let folded = fold(&g);
        // compute nodes are gone: inputs + one ConstFill remain
        assert!(folded.nodes.len() <= g.input_shapes.len() + 1, "{}", folded.render());
        assert_eq!(folded, fold_wholesale(&g), "patch fold diverges from the wholesale reference");
        let mut rng = Pcg::seed(1);
        let ins: Vec<Tensor> = g
            .input_shapes
            .iter()
            .map(|s| Tensor::randn(s.clone(), &mut rng, 1.0))
            .collect();
        let want = eval(&g, &ins).unwrap();
        let got = eval(&folded, &ins).unwrap();
        assert_eq!(got[0].shape, want[0].shape);
        assert!(got[0].allclose(&want[0], 1e-5, 1e-5));
    }

    #[test]
    fn non_constant_graph_untouched() {
        let mut b = GraphBuilder::new("live");
        let x = b.input(Shape::of(&[4, 4]));
        let r = b.unary(UnaryKind::Relu, x);
        let g = b.finish(vec![r]);
        assert!(!output_is_constant(&g));
        let folded = fold(&g);
        let mut rng = Pcg::seed(2);
        let ins = vec![Tensor::randn(Shape::of(&[4, 4]), &mut rng, 1.0)];
        assert!(eval(&folded, &ins).unwrap()[0].allclose(&eval(&g, &ins).unwrap()[0], 1e-6, 1e-6));
    }

    #[test]
    fn mul_by_zero_const_annihilates() {
        let mut b = GraphBuilder::new("z");
        let x = b.input(Shape::of(&[4]));
        let z = b.push(Op::ConstFill { value: 0.0, shape: Shape::of(&[4]) });
        let m = b.binary(BinaryKind::Mul, x, z);
        let g = b.finish(vec![m]);
        assert!(output_is_constant(&g));
    }

    #[test]
    fn singleton_sum_also_identity() {
        let mut b = GraphBuilder::new("s");
        let x = b.input(Shape::of(&[4, 1]));
        let s = b.reduce(ReduceKind::Sum, 1, x);
        let d = b.binary(BinaryKind::Sub, s, x);
        let g = b.finish(vec![d]);
        // sum over singleton == identity, so d == x - x == 0
        assert!(output_is_constant(&g));
    }

    #[test]
    fn fold_preserves_semantics_on_random_graphs() {
        // property: fold(g) ≡ g on the §7.3 graph for several seeds
        let g = gemm_max_subtract_gelu();
        let folded = fold(&g);
        for seed in 0..5 {
            let mut rng = Pcg::seed(seed);
            let ins: Vec<Tensor> = g
                .input_shapes
                .iter()
                .map(|s| Tensor::randn(s.clone(), &mut rng, 2.0))
                .collect();
            let want = eval(&g, &ins).unwrap();
            let got = eval(&folded, &ins).unwrap();
            assert!(got[0].allclose(&want[0], 1e-4, 1e-4));
        }
    }
}
