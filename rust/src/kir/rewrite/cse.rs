//! Common-subexpression elimination: identical ops over identical
//! operands collapse to one node.  (Synthesized programs frequently
//! duplicate work — e.g. recomputing sigmoid(x) for swish — and CSE is
//! one of the cheap wins a refinement iteration can apply.)

use crate::kir::graph::{Graph, Node, NodeId};
use crate::kir::op::Op;
use crate::kir::patch::GraphPatch;
use std::collections::HashMap;

/// Structural key for an op (operands already canonicalized).
fn key(op: &Op) -> String {
    format!("{op:?}")
}

/// Stage CSE as a patch: later duplicates redirect to their first
/// (canonical) occurrence, and the prune pass drops the dead copies.
/// Keys are computed over canonical *base* ids — the canonical map is
/// injective into the compacted graph, so this merges exactly the pairs
/// the wholesale pass merges.
pub fn patch(g: &Graph) -> GraphPatch<'_> {
    let mut seen: HashMap<String, NodeId> = HashMap::new();
    let mut canon: Vec<NodeId> = Vec::with_capacity(g.nodes.len());
    let mut p = GraphPatch::new(g);
    p.prune();
    for (id, n) in g.nodes.iter().enumerate() {
        let op = n.op.map_operands(|o| canon[o]);
        let k = key(&op);
        if let Some(&existing) = seen.get(&k) {
            canon.push(existing);
            p.redirect(id, existing).expect("cse: identical ops share a shape");
        } else {
            seen.insert(k, id);
            canon.push(id);
        }
    }
    p
}

/// Eliminate duplicate subexpressions.  Input nodes are never merged
/// (each `Input{idx}` is unique by idx anyway).  Patch-based; requires
/// a structurally valid graph.
pub fn eliminate(g: &Graph) -> Graph {
    patch(g).apply().expect("cse patch applies to a structurally valid graph").0
}

/// The original clone-and-rebuild CSE, kept as the differential
/// reference for the patch-vs-whole harness.
pub fn eliminate_wholesale(g: &Graph) -> Graph {
    let mut seen: HashMap<String, NodeId> = HashMap::new();
    let mut remap: Vec<NodeId> = Vec::with_capacity(g.nodes.len());
    let mut nodes: Vec<Node> = Vec::new();
    for n in &g.nodes {
        let op = n.op.map_operands(|o| remap[o]);
        let k = key(&op);
        if let Some(&existing) = seen.get(&k) {
            remap.push(existing);
        } else {
            nodes.push(Node { op, shape: n.shape.clone() });
            let id = nodes.len() - 1;
            seen.insert(k, id);
            remap.push(id);
        }
    }
    super::dce_wholesale(&Graph {
        name: g.name.clone(),
        nodes,
        input_shapes: g.input_shapes.clone(),
        outputs: g.outputs.iter().map(|&o| remap[o]).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::interp::eval;
    use crate::kir::op::{BinaryKind, UnaryKind};
    use crate::tensor::{Shape, Tensor};
    use crate::util::rng::Pcg;

    #[test]
    fn merges_duplicate_sigmoid() {
        let mut b = GraphBuilder::new("dup");
        let x = b.input(Shape::of(&[8]));
        let s1 = b.unary(UnaryKind::Sigmoid, x);
        let s2 = b.unary(UnaryKind::Sigmoid, x);
        let m = b.binary(BinaryKind::Mul, s1, s2);
        let g = b.finish(vec![m]);
        let c = eliminate(&g);
        assert_eq!(c.nodes.len(), 3); // input, sigmoid, mul
        let mut rng = Pcg::seed(0);
        let ins = vec![Tensor::randn(Shape::of(&[8]), &mut rng, 1.0)];
        assert!(eval(&c, &ins).unwrap()[0].allclose(&eval(&g, &ins).unwrap()[0], 1e-6, 1e-6));
    }

    #[test]
    fn distinct_ops_not_merged() {
        let mut b = GraphBuilder::new("no");
        let x = b.input(Shape::of(&[8]));
        let s = b.unary(UnaryKind::Sigmoid, x);
        let t = b.unary(UnaryKind::Tanh, x);
        let m = b.binary(BinaryKind::Mul, s, t);
        let g = b.finish(vec![m]);
        assert_eq!(eliminate(&g).nodes.len(), g.nodes.len());
    }

    #[test]
    fn transitive_merge() {
        // relu(sig(x)) twice -> single chain
        let mut b = GraphBuilder::new("tr");
        let x = b.input(Shape::of(&[4]));
        let s1 = b.unary(UnaryKind::Sigmoid, x);
        let r1 = b.unary(UnaryKind::Relu, s1);
        let s2 = b.unary(UnaryKind::Sigmoid, x);
        let r2 = b.unary(UnaryKind::Relu, s2);
        let m = b.binary(BinaryKind::Add, r1, r2);
        let g = b.finish(vec![m]);
        let c = eliminate(&g);
        assert_eq!(c.nodes.len(), 4); // x, sig, relu, add
        assert_eq!(c, eliminate_wholesale(&g), "patch cse diverges from the wholesale reference");
    }
}
