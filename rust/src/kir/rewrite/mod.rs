//! Graph rewrites the generation agent can discover.
//!
//! Each rewrite is semantics-preserving (property-tested: rewritten
//! graph ≡ original numerics on random inputs).  They correspond to the
//! optimizations the paper observed LLMs finding:
//! - [`fusion`] — fusion-group discovery (the dominant §5.1 optimization);
//! - [`constant_fold`] — §7.3 invariance exploitation (constant-output
//!   collapse of Conv3dGroupNormMean / GemmMaxSubtractGELU-style chains);
//! - [`algebraic`] — §7.4 computational-graph reduction (the
//!   sum∘(matmul+bias) → matvec collapse of L2 problem 12);
//! - [`cse`] — common-subexpression elimination.
//!
//! Every pass is *patch-based*: it stages its edits as a
//! [`GraphPatch`](super::patch::GraphPatch) against the immutable input
//! graph and applies them atomically.  The whole-graph entry points
//! below are thin wrappers over the patch path, and each pass keeps its
//! original clone-and-rebuild form as a `*_wholesale` reference that
//! the differential harness (`tests/conformance.rs`) sweeps ≥1,200
//! seeds per pass against, asserting bit-identical results.

pub mod fusion;
pub mod constant_fold;
pub mod algebraic;
pub mod cse;

use super::graph::Graph;
use super::patch::GraphPatch;

/// The rewrites a synthesized program may apply, in a canonical order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rewrite {
    /// Collapse provably-constant outputs to a ConstFill (§7.3).
    ConstantFold,
    /// Algebraic reduction of reduce∘matmul chains (§7.4).
    AlgebraicReduce,
    /// Deduplicate identical subexpressions.
    Cse,
}

impl Rewrite {
    pub fn name(&self) -> &'static str {
        match self {
            Rewrite::ConstantFold => "constant_fold",
            Rewrite::AlgebraicReduce => "algebraic_reduce",
            Rewrite::Cse => "cse",
        }
    }

    /// Apply this rewrite, returning the (possibly unchanged) graph.
    pub fn apply(&self, g: &Graph) -> Graph {
        let out = match self {
            Rewrite::ConstantFold => constant_fold::fold(g),
            Rewrite::AlgebraicReduce => algebraic::reduce_matmul_chains(g),
            Rewrite::Cse => cse::eliminate(g),
        };
        if crate::obs::enabled() {
            crate::obs::counter("rewrite.nodes_visited", g.nodes.len() as u64);
            crate::obs::counter(
                &format!("rewrite.{}.applied", self.name()),
                u64::from(out != *g),
            );
            crate::obs::counter(
                &format!("rewrite.{}.nodes_out", self.name()),
                out.nodes.len() as u64,
            );
        }
        out
    }
}

/// Apply a list of rewrites in order.
pub fn apply_all(g: &Graph, rewrites: &[Rewrite]) -> Graph {
    let mut out = g.clone();
    for r in rewrites {
        out = r.apply(&out);
    }
    out
}

/// Drop nodes not reachable from the outputs (shared cleanup pass used
/// by the rewrites).  Preserves input nodes (interface stability).
/// Patch-based: a prune-only [`GraphPatch`] applied to `g`.  Requires a
/// structurally valid graph (all call sites pass reference graphs).
pub fn dce(g: &Graph) -> Graph {
    let mut p = GraphPatch::new(g);
    p.prune();
    p.apply().expect("dce patch applies to a structurally valid graph").0
}

/// The original clone-and-rebuild DCE, kept as the differential
/// reference for the patch-vs-whole harness.
pub fn dce_wholesale(g: &Graph) -> Graph {
    let mut live = vec![false; g.nodes.len()];
    let mut stack: Vec<usize> = g.outputs.clone();
    while let Some(id) = stack.pop() {
        if live[id] {
            continue;
        }
        live[id] = true;
        stack.extend(g.nodes[id].op.operands());
    }
    // keep all Input nodes so the calling convention never changes
    for (i, n) in g.nodes.iter().enumerate() {
        if matches!(n.op, super::op::Op::Input { .. }) {
            live[i] = true;
        }
    }
    let mut remap = vec![usize::MAX; g.nodes.len()];
    let mut nodes = Vec::new();
    for (i, n) in g.nodes.iter().enumerate() {
        if live[i] {
            remap[i] = nodes.len();
            nodes.push(super::graph::Node {
                op: n.op.map_operands(|o| remap[o]),
                shape: n.shape.clone(),
            });
        }
    }
    Graph {
        name: g.name.clone(),
        nodes,
        input_shapes: g.input_shapes.clone(),
        outputs: g.outputs.iter().map(|&o| remap[o]).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::tensor::Shape;

    #[test]
    fn dce_removes_dead_compute_keeps_inputs() {
        let mut b = GraphBuilder::new("d");
        let x = b.input(Shape::of(&[4]));
        let _dead = b.unary(UnaryKind::Exp, x);
        let live = b.unary(UnaryKind::Relu, x);
        let g = b.finish(vec![live]);
        let pruned = dce(&g);
        assert_eq!(pruned.nodes.len(), 2); // input + relu
        assert_eq!(pruned.input_shapes.len(), 1);
        assert!(crate::kir::validate::validate(&pruned).is_ok());
        assert_eq!(pruned, dce_wholesale(&g), "patch dce diverges from the wholesale reference");
    }
}
