//! KIR interpreter: evaluate a graph with the reference tensor ops.
//!
//! This produces the numerics used in verification — the candidate's
//! rewritten graph is evaluated and compared against the problem's
//! reference graph on the same seeded inputs (the paper's *numerical or
//! shape mismatch* vs *correct* distinction, §3.3).

use super::graph::{Graph, NodeId};
use super::op::{BinaryKind, Op, ReduceKind, UnaryKind};
use crate::tensor::{ops, Tensor};
use anyhow::{bail, Result};

/// Evaluate `g` on `inputs` (one tensor per declared input).
pub fn eval(g: &Graph, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    if inputs.len() != g.input_shapes.len() {
        bail!(
            "expected {} inputs, got {}",
            g.input_shapes.len(),
            inputs.len()
        );
    }
    for (i, (t, s)) in inputs.iter().zip(&g.input_shapes).enumerate() {
        if &t.shape != s {
            bail!("input {i} shape {} != declared {s}", t.shape);
        }
    }
    let mut vals: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    for (id, node) in g.nodes.iter().enumerate() {
        let v = eval_node(g, id, &node.op, inputs, &vals)?;
        if v.shape != node.shape {
            bail!(
                "node %{id} ({}) produced {} but graph annotates {}",
                node.op.mnemonic(),
                v.shape,
                node.shape
            );
        }
        vals[id] = Some(v);
    }
    Ok(g.outputs
        .iter()
        .map(|&o| vals[o].clone().expect("output evaluated"))
        .collect())
}

fn get<'a>(vals: &'a [Option<Tensor>], id: NodeId) -> &'a Tensor {
    vals[id].as_ref().expect("topological order")
}

fn eval_node(
    _g: &Graph,
    _id: NodeId,
    op: &Op,
    inputs: &[Tensor],
    vals: &[Option<Tensor>],
) -> Result<Tensor> {
    Ok(match op {
        Op::Input { idx } => inputs[*idx].clone(),
        Op::ConstFill { value, shape } => Tensor::full(shape.clone(), *value),
        Op::Unary { kind, input } => {
            let x = get(vals, *input);
            match kind {
                UnaryKind::Relu => ops::relu(x),
                UnaryKind::Sigmoid => ops::sigmoid(x),
                UnaryKind::Swish => ops::swish(x),
                UnaryKind::Gelu => ops::gelu(x),
                UnaryKind::Tanh => ops::tanh(x),
                UnaryKind::Exp => ops::exp(x),
                UnaryKind::Neg => ops::neg(x),
                UnaryKind::Square => ops::square(x),
                UnaryKind::Sqrt => ops::sqrt(x),
            }
        }
        Op::Binary { kind, lhs, rhs } => {
            let (a, b) = (get(vals, *lhs), get(vals, *rhs));
            match kind {
                BinaryKind::Add => ops::add(a, b),
                BinaryKind::Sub => ops::sub(a, b),
                BinaryKind::Mul => ops::mul(a, b),
                BinaryKind::Div => ops::div(a, b),
                BinaryKind::Max => ops::maximum(a, b),
            }
        }
        Op::Matmul { lhs, rhs } => ops::matmul(get(vals, *lhs), get(vals, *rhs)),
        Op::Transpose2 { input } => ops::transpose2(get(vals, *input)),
        Op::Reduce { kind, axis, input } => {
            let k = match kind {
                ReduceKind::Sum => ops::Reduce::Sum,
                ReduceKind::Max => ops::Reduce::Max,
                ReduceKind::Mean => ops::Reduce::Mean,
                ReduceKind::LogSumExp => ops::Reduce::LogSumExp,
            };
            ops::reduce(get(vals, *input), *axis, k)
        }
        Op::Softmax { input } => ops::softmax(get(vals, *input)),
        Op::Layernorm { input, gamma, beta } => {
            ops::layernorm(get(vals, *input), get(vals, *gamma), get(vals, *beta), 1e-5)
        }
        Op::Attention { q, k, v } => ops::attention(get(vals, *q), get(vals, *k), get(vals, *v)),
        Op::Conv2d { input, weight, stride, padding } => {
            ops::conv2d(get(vals, *input), get(vals, *weight), *stride, *padding)
        }
        Op::DepthwiseConv2d { input, weight, stride, padding } => {
            ops::depthwise_conv2d(get(vals, *input), get(vals, *weight), *stride, *padding)
        }
        Op::MaxPool2d { input, k, stride } => ops::maxpool2d(get(vals, *input), *k, *stride),
        Op::AvgPool2d { input, k, stride } => ops::avgpool2d(get(vals, *input), *k, *stride),
        Op::GlobalAvgPool { input } => ops::global_avgpool(get(vals, *input)),
        Op::Concat { inputs: ins, axis } => {
            let ts: Vec<&Tensor> = ins.iter().map(|&i| get(vals, i)).collect();
            ops::concat(&ts, *axis)
        }
        Op::Reshape { input, shape } => get(vals, *input).reshape(shape.clone()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::{ReduceKind, UnaryKind};
    use crate::tensor::Shape;
    use crate::util::rng::Pcg;

    #[test]
    fn evaluates_mlp() {
        let mut b = GraphBuilder::new("mlp");
        let x = b.input(Shape::of(&[3, 4]));
        let w = b.input(Shape::of(&[4, 5]));
        let bias = b.input(Shape::of(&[5]));
        let m = b.matmul(x, w);
        let a = b.add(m, bias);
        let r = b.unary(UnaryKind::Relu, a);
        let g = b.finish(vec![r]);

        let mut rng = Pcg::seed(0);
        let ins = vec![
            Tensor::randn(Shape::of(&[3, 4]), &mut rng, 1.0),
            Tensor::randn(Shape::of(&[4, 5]), &mut rng, 1.0),
            Tensor::randn(Shape::of(&[5]), &mut rng, 1.0),
        ];
        let out = eval(&g, &ins).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, Shape::of(&[3, 5]));
        assert!(out[0].data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn rejects_wrong_input_count() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(Shape::of(&[2]));
        let g = b.finish(vec![x]);
        assert!(eval(&g, &[]).is_err());
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let mut b = GraphBuilder::new("t");
        let x = b.input(Shape::of(&[2]));
        let g = b.finish(vec![x]);
        assert!(eval(&g, &[Tensor::zeros(Shape::of(&[3]))]).is_err());
    }

    #[test]
    fn reduce_chain_matches_manual() {
        let mut b = GraphBuilder::new("chain");
        let x = b.input(Shape::of(&[2, 3]));
        let s = b.reduce(ReduceKind::Sum, 1, x);
        let g = b.finish(vec![s]);
        let t = Tensor::new(Shape::of(&[2, 3]), vec![1., 2., 3., 4., 5., 6.]);
        let out = eval(&g, &[t]).unwrap();
        assert_eq!(out[0].data, vec![6.0, 15.0]);
    }

    #[test]
    fn multiple_outputs() {
        let mut b = GraphBuilder::new("multi");
        let x = b.input(Shape::of(&[4]));
        let r = b.unary(UnaryKind::Relu, x);
        let n = b.unary(UnaryKind::Neg, x);
        let g = b.finish(vec![r, n]);
        let t = Tensor::new(Shape::of(&[4]), vec![-1., 2., -3., 4.]);
        let out = eval(&g, &[t]).unwrap();
        assert_eq!(out[0].data, vec![0., 2., 0., 4.]);
        assert_eq!(out[1].data, vec![1., -2., 3., -4.]);
    }
}
