//! KIR operations.  All tensors are f32; shapes are static.

use crate::tensor::Shape;

pub type NodeId = usize;

/// Unary elementwise ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnaryKind {
    Relu,
    Sigmoid,
    Swish,
    Gelu,
    Tanh,
    Exp,
    Neg,
    Square,
    Sqrt,
}

impl UnaryKind {
    pub const ALL: [UnaryKind; 9] = [
        UnaryKind::Relu,
        UnaryKind::Sigmoid,
        UnaryKind::Swish,
        UnaryKind::Gelu,
        UnaryKind::Tanh,
        UnaryKind::Exp,
        UnaryKind::Neg,
        UnaryKind::Square,
        UnaryKind::Sqrt,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            UnaryKind::Relu => "relu",
            UnaryKind::Sigmoid => "sigmoid",
            UnaryKind::Swish => "swish",
            UnaryKind::Gelu => "gelu",
            UnaryKind::Tanh => "tanh",
            UnaryKind::Exp => "exp",
            UnaryKind::Neg => "neg",
            UnaryKind::Square => "square",
            UnaryKind::Sqrt => "sqrt",
        }
    }

    /// Transcendental ops cost more flops per element in the cost model
    /// and are the ones a fast-math schedule accelerates (§7.2).
    pub fn is_transcendental(&self) -> bool {
        matches!(
            self,
            UnaryKind::Sigmoid | UnaryKind::Swish | UnaryKind::Gelu | UnaryKind::Tanh | UnaryKind::Exp
        )
    }
}

/// Binary elementwise ops (numpy broadcasting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinaryKind {
    Add,
    Sub,
    Mul,
    Div,
    Max,
}

impl BinaryKind {
    pub fn name(&self) -> &'static str {
        match self {
            BinaryKind::Add => "add",
            BinaryKind::Sub => "sub",
            BinaryKind::Mul => "mul",
            BinaryKind::Div => "div",
            BinaryKind::Max => "max",
        }
    }
}

/// Reductions (always keepdims).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    Sum,
    Max,
    Mean,
    LogSumExp,
}

impl ReduceKind {
    pub fn name(&self) -> &'static str {
        match self {
            ReduceKind::Sum => "sum",
            ReduceKind::Max => "max",
            ReduceKind::Mean => "mean",
            ReduceKind::LogSumExp => "logsumexp",
        }
    }
}

/// A KIR operation.  Operand order is semantic (lhs/rhs).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Graph input `idx` (includes weights — the problem spec declares
    /// all input shapes; data is generated from the problem seed).
    Input { idx: usize },
    /// Constant fill.
    ConstFill { value: f32, shape: Shape },
    Unary { kind: UnaryKind, input: NodeId },
    Binary { kind: BinaryKind, lhs: NodeId, rhs: NodeId },
    Matmul { lhs: NodeId, rhs: NodeId },
    Transpose2 { input: NodeId },
    Reduce { kind: ReduceKind, axis: usize, input: NodeId },
    Softmax { input: NodeId },
    Layernorm { input: NodeId, gamma: NodeId, beta: NodeId },
    Attention { q: NodeId, k: NodeId, v: NodeId },
    Conv2d { input: NodeId, weight: NodeId, stride: usize, padding: usize },
    DepthwiseConv2d { input: NodeId, weight: NodeId, stride: usize, padding: usize },
    MaxPool2d { input: NodeId, k: usize, stride: usize },
    AvgPool2d { input: NodeId, k: usize, stride: usize },
    GlobalAvgPool { input: NodeId },
    Concat { inputs: Vec<NodeId>, axis: usize },
    Reshape { input: NodeId, shape: Shape },
}

impl Op {
    /// Node ids this op reads.
    pub fn operands(&self) -> Vec<NodeId> {
        match self {
            Op::Input { .. } | Op::ConstFill { .. } => vec![],
            Op::Unary { input, .. }
            | Op::Transpose2 { input }
            | Op::Reduce { input, .. }
            | Op::Softmax { input }
            | Op::MaxPool2d { input, .. }
            | Op::AvgPool2d { input, .. }
            | Op::GlobalAvgPool { input }
            | Op::Reshape { input, .. } => vec![*input],
            Op::Binary { lhs, rhs, .. } | Op::Matmul { lhs, rhs } => vec![*lhs, *rhs],
            Op::Layernorm { input, gamma, beta } => vec![*input, *gamma, *beta],
            Op::Attention { q, k, v } => vec![*q, *k, *v],
            Op::Conv2d { input, weight, .. } | Op::DepthwiseConv2d { input, weight, .. } => {
                vec![*input, *weight]
            }
            Op::Concat { inputs, .. } => inputs.clone(),
        }
    }

    /// Rewrite operand ids through a mapping (used by rewrites/CSE).
    pub fn map_operands(&self, mut f: impl FnMut(NodeId) -> NodeId) -> Op {
        let mut op = self.clone();
        match &mut op {
            Op::Input { .. } | Op::ConstFill { .. } => {}
            Op::Unary { input, .. }
            | Op::Transpose2 { input }
            | Op::Reduce { input, .. }
            | Op::Softmax { input }
            | Op::MaxPool2d { input, .. }
            | Op::AvgPool2d { input, .. }
            | Op::GlobalAvgPool { input }
            | Op::Reshape { input, .. } => *input = f(*input),
            Op::Binary { lhs, rhs, .. } | Op::Matmul { lhs, rhs } => {
                *lhs = f(*lhs);
                *rhs = f(*rhs);
            }
            Op::Layernorm { input, gamma, beta } => {
                *input = f(*input);
                *gamma = f(*gamma);
                *beta = f(*beta);
            }
            Op::Attention { q, k, v } => {
                *q = f(*q);
                *k = f(*k);
                *v = f(*v);
            }
            Op::Conv2d { input, weight, .. } | Op::DepthwiseConv2d { input, weight, .. } => {
                *input = f(*input);
                *weight = f(*weight);
            }
            Op::Concat { inputs, .. } => {
                for i in inputs.iter_mut() {
                    *i = f(*i);
                }
            }
        }
        op
    }

    /// Short mnemonic for logs/profiles.
    pub fn mnemonic(&self) -> String {
        match self {
            Op::Input { idx } => format!("input{idx}"),
            Op::ConstFill { .. } => "const".into(),
            Op::Unary { kind, .. } => kind.name().into(),
            Op::Binary { kind, .. } => kind.name().into(),
            Op::Matmul { .. } => "matmul".into(),
            Op::Transpose2 { .. } => "transpose".into(),
            Op::Reduce { kind, axis, .. } => format!("reduce_{}{axis}", kind.name()),
            Op::Softmax { .. } => "softmax".into(),
            Op::Layernorm { .. } => "layernorm".into(),
            Op::Attention { .. } => "attention".into(),
            Op::Conv2d { .. } => "conv2d".into(),
            Op::DepthwiseConv2d { .. } => "dwconv2d".into(),
            Op::MaxPool2d { .. } => "maxpool2d".into(),
            Op::AvgPool2d { .. } => "avgpool2d".into(),
            Op::GlobalAvgPool { .. } => "gavgpool".into(),
            Op::Concat { .. } => "concat".into(),
            Op::Reshape { .. } => "reshape".into(),
        }
    }

    /// Is this op elementwise (fusable into a producer's epilogue)?
    pub fn is_elementwise(&self) -> bool {
        matches!(self, Op::Unary { .. } | Op::Binary { .. })
    }

    /// Is this a FLOP-dense op (matmul/conv family) that anchors fusion?
    pub fn is_compute_anchor(&self) -> bool {
        matches!(
            self,
            Op::Matmul { .. } | Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Attention { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operands_cover_all_variants() {
        let op = Op::Attention { q: 1, k: 2, v: 3 };
        assert_eq!(op.operands(), vec![1, 2, 3]);
        assert_eq!(Op::Input { idx: 0 }.operands(), Vec::<NodeId>::new());
        assert_eq!(
            Op::Concat { inputs: vec![4, 5], axis: 1 }.operands(),
            vec![4, 5]
        );
    }

    #[test]
    fn map_operands_shifts_ids() {
        let op = Op::Binary { kind: BinaryKind::Add, lhs: 3, rhs: 4 };
        let shifted = op.map_operands(|i| i + 10);
        assert_eq!(shifted.operands(), vec![13, 14]);
    }

    #[test]
    fn transcendental_classification() {
        assert!(UnaryKind::Swish.is_transcendental());
        assert!(!UnaryKind::Relu.is_transcendental());
    }
}
