//! Incremental graph editing: staged patches over an immutable base
//! graph, applied atomically, with dirty-region tracking.
//!
//! The rewrite passes used to be `Graph -> Graph` functions that clone
//! and rebuild the whole node list per step — O(graph) per move, which
//! caps graph size now that level-4 whole-model DAGs exist.  A
//! [`GraphPatch`] instead *stages* edits against a borrowed base graph
//! (the tract `ModelPatch` idiom): added nodes get fresh ids past the
//! base length, replacements swap an op in place, redirects repoint
//! every user of one value at another.  [`GraphPatch::apply`] resolves
//! the staged edits into a new validated graph in one pass and reports
//! which surviving nodes changed as a [`DirtySet`], so consumers
//! (`search/oracle.rs` re-pricing, fusion-plan refresh) can rebuild
//! only the dirty region.
//!
//! Atomicity: `apply` consumes the patch, never mutates the base, and
//! returns `Err` — yielding nothing — on conflicting edits, cycles, or
//! validation failure of the edited graph.  An empty patch is the
//! identity (bit-identical clone of the base).
//!
//! The ported passes are differentially tested bit-identical to their
//! wholesale forms over ≥1,200 fuzz seeds each (`tests/conformance.rs`).

use super::graph::{infer_shape, Graph, Node, NodeId};
use super::op::Op;
use super::validate::validate;
use crate::tensor::Shape;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Which surviving nodes of a patched graph differ from the base, plus
/// the id correspondence needed to carry per-node results across.
#[derive(Debug, Clone)]
pub struct DirtySet {
    /// `old_to_new[i]` = the new id of base node `i`, or `None` if the
    /// node was pruned.  Injective on the survivors.
    pub old_to_new: Vec<Option<NodeId>>,
    /// Per *new* node id: did this node change relative to the base?
    /// Clean (`false`) guarantees: same op and shape, operand list is
    /// the image of the old operand list, user multiset and
    /// output-membership preserved.  Per-node derived facts (flops,
    /// external traffic, fusion decisions) are therefore reusable.
    dirty: Vec<bool>,
}

impl DirtySet {
    /// The identity dirty set: nothing changed, ids map to themselves.
    pub fn identity(n: usize) -> DirtySet {
        DirtySet { old_to_new: (0..n).map(Some).collect(), dirty: vec![false; n] }
    }

    /// Number of nodes in the *new* graph this set describes.
    pub fn len(&self) -> usize {
        self.dirty.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dirty.is_empty()
    }

    /// Is new node `id` dirty?
    pub fn is_dirty(&self, id: NodeId) -> bool {
        self.dirty[id]
    }

    /// Dirty new node ids, ascending.
    pub fn dirty_ids(&self) -> Vec<NodeId> {
        (0..self.dirty.len()).filter(|&i| self.dirty[i]).collect()
    }

    /// How many new nodes are dirty.
    pub fn count(&self) -> usize {
        self.dirty.iter().filter(|&&d| d).count()
    }

    /// Dirty nodes plus everything downstream of them in `g` (nodes
    /// whose value can differ because an input to their cone changed).
    /// One forward pass: operands precede users in a KIR graph.
    pub fn forward_closure(&self, g: &Graph) -> Vec<bool> {
        assert_eq!(self.dirty.len(), g.nodes.len(), "dirty set is for a different graph");
        let mut m = self.dirty.clone();
        for id in 0..g.nodes.len() {
            if !m[id] && g.nodes[id].op.operands().iter().any(|&o| m[o]) {
                m[id] = true;
            }
        }
        m
    }

    /// Dirty nodes plus everything upstream of them in `g` (the cone
    /// that must be re-examined to re-derive a dirty node).  One
    /// reverse pass.
    pub fn backward_closure(&self, g: &Graph) -> Vec<bool> {
        assert_eq!(self.dirty.len(), g.nodes.len(), "dirty set is for a different graph");
        let mut m = self.dirty.clone();
        for id in (0..g.nodes.len()).rev() {
            if m[id] {
                for o in g.nodes[id].op.operands() {
                    m[o] = true;
                }
            }
        }
        m
    }
}

/// Staged edits against a borrowed immutable base graph.
///
/// Edit kinds:
/// - [`add`](GraphPatch::add): append a node (fresh id past the base);
/// - [`replace`](GraphPatch::replace): swap a base node's op in place;
/// - [`redirect`](GraphPatch::redirect): repoint every user (and
///   output) of one value at a same-shaped other value;
/// - [`rewire_output`](GraphPatch::rewire_output) /
///   [`set_outputs`](GraphPatch::set_outputs): change the output list;
/// - [`prune`](GraphPatch::prune): drop dead non-input nodes on apply
///   (the DCE the wholesale passes ran);
/// - [`resort`](GraphPatch::resort): Kahn re-sort on apply, for edits
///   that break the id-ordered topological invariant.
///
/// Conflicting edits (two replaces of one node, redirecting a replaced
/// node, …) are rejected at stage time with an error naming both node
/// ids involved.
pub struct GraphPatch<'g> {
    base: &'g Graph,
    adds: Vec<Node>,
    replaces: BTreeMap<NodeId, Node>,
    redirects: BTreeMap<NodeId, NodeId>,
    output_rewires: BTreeMap<usize, NodeId>,
    new_outputs: Option<Vec<NodeId>>,
    prune: bool,
    resort: bool,
}

impl<'g> GraphPatch<'g> {
    pub fn new(base: &'g Graph) -> GraphPatch<'g> {
        GraphPatch {
            base,
            adds: Vec::new(),
            replaces: BTreeMap::new(),
            redirects: BTreeMap::new(),
            output_rewires: BTreeMap::new(),
            new_outputs: None,
            prune: false,
            resort: false,
        }
    }

    /// No staged edits and no apply-time passes: applying yields a
    /// bit-identical clone of the base.
    pub fn is_empty(&self) -> bool {
        self.adds.is_empty()
            && self.replaces.is_empty()
            && self.redirects.is_empty()
            && self.output_rewires.is_empty()
            && self.new_outputs.is_none()
            && !self.prune
            && !self.resort
    }

    /// Total node count of the virtual (pre-prune) edited graph.
    fn total(&self) -> usize {
        self.base.nodes.len() + self.adds.len()
    }

    /// Run dead-code elimination on apply (keeps all `Input` nodes, as
    /// the wholesale `rewrite::dce` always did).
    pub fn prune(&mut self) {
        self.prune = true;
    }

    /// Kahn-re-sort the node list on apply.  Required when staged nodes
    /// replace values defined *before* their own fresh ids (e.g. the
    /// algebraic rewrite's appended replacement chain).
    pub fn resort(&mut self) {
        self.resort = true;
    }

    /// Follow staged redirects to the final target of `id`.
    fn resolve(&self, mut id: NodeId) -> NodeId {
        while let Some(&t) = self.redirects.get(&id) {
            id = t;
        }
        id
    }

    /// Shape of a virtual node (base, replaced, or added).
    fn shape_of(&self, id: NodeId) -> Shape {
        let nb = self.base.nodes.len();
        if id < nb {
            match self.replaces.get(&id) {
                Some(n) => n.shape.clone(),
                None => self.base.nodes[id].shape.clone(),
            }
        } else {
            self.adds[id - nb].shape.clone()
        }
    }

    /// Unresolved (as staged) op of a virtual node.
    fn raw_op(&self, id: NodeId) -> &Op {
        let nb = self.base.nodes.len();
        if id < nb {
            match self.replaces.get(&id) {
                Some(n) => &n.op,
                None => &self.base.nodes[id].op,
            }
        } else {
            &self.adds[id - nb].op
        }
    }

    /// Effective op of a virtual node with redirects resolved.
    fn eff_op(&self, id: NodeId) -> Op {
        self.raw_op(id).map_operands(|o| self.resolve(o))
    }

    /// Stage a new node.  Operands may reference base nodes or earlier
    /// staged adds; the shape is inferred eagerly (ill-typed ops are
    /// rejected here, mirroring `GraphBuilder`).  Returns the fresh id.
    pub fn add(&mut self, op: Op) -> Result<NodeId> {
        let id = self.total();
        for o in op.operands() {
            if o >= id {
                bail!("patch: staged node %{id} references undefined value %{o}");
            }
        }
        let shape = infer_shape(&op, &|i| self.shape_of(i), &self.base.input_shapes)?;
        self.adds.push(Node { op, shape });
        Ok(id)
    }

    /// Stage an in-place op replacement for base node `id`.  The new
    /// op's shape is re-inferred; operands must precede `id` or be
    /// staged adds (the latter requires [`resort`](GraphPatch::resort)).
    pub fn replace(&mut self, id: NodeId, op: Op) -> Result<()> {
        let nb = self.base.nodes.len();
        if id >= nb {
            bail!("patch: cannot replace %{id}: base graph has {nb} nodes");
        }
        if let Some(&t) = self.redirects.get(&id) {
            bail!("patch conflict: %{id} is already redirected to %{t}; cannot also replace %{id}");
        }
        if self.replaces.contains_key(&id) {
            bail!("patch conflict: %{id} already has a staged replacement; refusing a second replace of %{id}");
        }
        for o in op.operands() {
            if o >= self.total() {
                bail!("patch: replacement for %{id} references undefined value %{o}");
            }
            if o >= id && o < nb && !self.resort {
                bail!("patch: replacement for %{id} reads %{o} out of order (stage resort() first)");
            }
        }
        let shape = infer_shape(&op, &|i| self.shape_of(i), &self.base.input_shapes)?;
        self.replaces.insert(id, Node { op, shape });
        Ok(())
    }

    /// Stage a redirect: every user (and output occurrence) of `from`
    /// reads `to` instead.  `to` must carry the same shape — redirects
    /// are value substitutions, not retypings.
    pub fn redirect(&mut self, from: NodeId, to: NodeId) -> Result<()> {
        let nb = self.base.nodes.len();
        if from >= nb {
            bail!("patch: cannot redirect staged node %{from} (base graph has {nb} nodes)");
        }
        if to >= self.total() {
            bail!("patch: redirect %{from} -> %{to} targets an undefined value");
        }
        if let Some(&t) = self.redirects.get(&from) {
            bail!("patch conflict: %{from} already redirected to %{t}; cannot redirect %{from} -> %{to}");
        }
        if self.replaces.contains_key(&from) {
            bail!("patch conflict: %{from} already has a staged replacement; cannot redirect %{from} -> %{to}");
        }
        if self.resolve(to) == from {
            bail!("patch conflict: redirect %{from} -> %{to} closes a redirect cycle");
        }
        let (sf, st) = (self.shape_of(from), self.shape_of(to));
        if sf != st {
            bail!("patch: redirect %{from} -> %{to} changes shape {sf} -> {st}");
        }
        self.redirects.insert(from, to);
        Ok(())
    }

    /// Stage a positional output rewire: output slot `pos` reads `to`.
    pub fn rewire_output(&mut self, pos: usize, to: NodeId) -> Result<()> {
        if self.new_outputs.is_some() {
            bail!("patch conflict: outputs were wholesale-set; cannot also rewire slot {pos} -> %{to}");
        }
        if pos >= self.base.outputs.len() {
            bail!("patch: output slot {pos} out of range (graph has {})", self.base.outputs.len());
        }
        if to >= self.total() {
            bail!("patch: output rewire slot {pos} -> %{to} targets an undefined value");
        }
        if let Some(&prev) = self.output_rewires.get(&pos) {
            bail!("patch conflict: output slot {pos} already rewired to %{prev}; cannot rewire it to %{to}");
        }
        self.output_rewires.insert(pos, to);
        Ok(())
    }

    /// Stage a wholesale output-list replacement (the shrinker's
    /// output-minimization move).
    pub fn set_outputs(&mut self, outs: Vec<NodeId>) -> Result<()> {
        if let Some((&pos, &to)) = self.output_rewires.iter().next() {
            bail!("patch conflict: output slot {pos} already rewired to %{to}; cannot wholesale-set outputs");
        }
        if self.new_outputs.is_some() {
            bail!("patch conflict: outputs already wholesale-set");
        }
        for &o in &outs {
            if o >= self.total() {
                bail!("patch: output references undefined value %{o}");
            }
        }
        self.new_outputs = Some(outs);
        Ok(())
    }

    /// Resolve the staged edits into a new graph.  Consumes the patch
    /// (it is built against exactly this base).  The base is never
    /// mutated; on any error nothing is produced.  The edited graph is
    /// validated before being returned, and comes with the [`DirtySet`]
    /// describing what changed.
    pub fn apply(self) -> Result<(Graph, DirtySet)> {
        let base = self.base;
        let nb = base.nodes.len();
        let total = self.total();
        if self.is_empty() {
            return Ok((base.clone(), DirtySet::identity(nb)));
        }

        // Outputs of the virtual graph, redirects resolved.
        let mut outs: Vec<NodeId> = match &self.new_outputs {
            Some(v) => v.clone(),
            None => {
                let mut v = base.outputs.clone();
                for (&pos, &to) in &self.output_rewires {
                    v[pos] = to;
                }
                v
            }
        };
        for o in outs.iter_mut() {
            *o = self.resolve(*o);
        }

        // Materialize, mapping every intermediate (virtual) id to its
        // final id — `None` for pruned nodes.
        let (out_g, int_to_final): (Graph, Vec<Option<NodeId>>) = if self.resort {
            let nodes: Vec<Node> = (0..total)
                .map(|i| Node { op: self.eff_op(i), shape: self.shape_of(i) })
                .collect();
            let order = kahn_order(&nodes)?;
            let mut remap = vec![0usize; total];
            for (new, &old) in order.iter().enumerate() {
                remap[old] = new;
            }
            let mut sorted = vec![Node { op: Op::Input { idx: 0 }, shape: Shape::scalar() }; total];
            for (old, node) in nodes.iter().enumerate() {
                sorted[remap[old]] =
                    Node { op: node.op.map_operands(|o| remap[o]), shape: node.shape.clone() };
            }
            let sorted_g = Graph {
                name: base.name.clone(),
                nodes: sorted,
                input_shapes: base.input_shapes.clone(),
                outputs: outs.iter().map(|&o| remap[o]).collect(),
            };
            if self.prune {
                let (pruned, prune_map) = prune_graph(&sorted_g);
                let int_to_final = (0..total).map(|i| prune_map[remap[i]]).collect();
                (pruned, int_to_final)
            } else {
                let int_to_final = (0..total).map(|i| Some(remap[i])).collect();
                (sorted_g, int_to_final)
            }
        } else {
            // Direct emit in id order.  With prune on, dead nodes are
            // never materialized at all — liveness runs over the
            // *virtual* ops, so a shrink candidate only ever builds its
            // live cone.
            let mut live = vec![!self.prune; total];
            if self.prune {
                let mut stack = outs.clone();
                while let Some(id) = stack.pop() {
                    if live[id] {
                        continue;
                    }
                    live[id] = true;
                    stack.extend(self.eff_op(id).operands());
                }
                // keep all Input nodes so the calling convention never
                // changes (same rule as the wholesale dce)
                for i in 0..total {
                    if matches!(self.raw_op(i), Op::Input { .. }) {
                        live[i] = true;
                    }
                }
            }
            let mut remap: Vec<Option<NodeId>> = vec![None; total];
            let mut nodes = Vec::new();
            for i in 0..total {
                if live[i] {
                    remap[i] = Some(nodes.len());
                    nodes.push(Node {
                        op: self.eff_op(i).map_operands(|o| remap[o].expect("live operand")),
                        shape: self.shape_of(i),
                    });
                }
            }
            let g = Graph {
                name: base.name.clone(),
                nodes,
                input_shapes: base.input_shapes.clone(),
                outputs: outs.iter().map(|&o| remap[o].expect("live output")).collect(),
            };
            (g, remap)
        };

        validate(&out_g)?;
        let dirty = self.dirty_set(&outs, &int_to_final, out_g.nodes.len());
        Ok((out_g, dirty))
    }

    /// Compute the dirty set in intermediate-id space, then map it
    /// through the final renumbering.  Over-approximates: anything a
    /// per-node derived fact could observe (content, operand identity
    /// or content, user multiset, output membership) marks the node.
    fn dirty_set(
        &self,
        outs_resolved: &[NodeId],
        int_to_final: &[Option<NodeId>],
        final_len: usize,
    ) -> DirtySet {
        let base = self.base;
        let nb = base.nodes.len();
        let total = self.total();
        let mut d = vec![false; total];
        // added nodes, and their operands (which gained a user)
        for (k, node) in self.adds.iter().enumerate() {
            d[nb + k] = true;
            for o in node.op.operands() {
                d[self.resolve(o)] = true;
            }
        }
        // replaced nodes, plus old and new operands (user-set change)
        for (&id, node) in &self.replaces {
            d[id] = true;
            for o in base.nodes[id].op.operands() {
                d[self.resolve(o)] = true;
            }
            for o in node.op.operands() {
                d[self.resolve(o)] = true;
            }
        }
        // redirect sources and targets
        for (&f, &t) in &self.redirects {
            d[f] = true;
            d[self.resolve(t)] = true;
        }
        // users whose operand identities changed (redirected operand)
        // or whose operand content changed (replaced operand)
        for i in 0..total {
            if d[i] {
                continue;
            }
            for o in self.raw_op(i).operands() {
                let r = self.resolve(o);
                if r != o || self.replaces.contains_key(&r) {
                    d[i] = true;
                    break;
                }
            }
        }
        // output-multiplicity changes
        let mut was_cnt = vec![0u32; total];
        for &o in &base.outputs {
            was_cnt[o] += 1;
        }
        let mut now_cnt = vec![0u32; total];
        for &o in outs_resolved {
            now_cnt[o] += 1;
        }
        for i in 0..total {
            if was_cnt[i] != now_cnt[i] {
                d[i] = true;
            }
        }
        // surviving operands of pruned nodes (they lost a user)
        for i in 0..total {
            if int_to_final[i].is_none() {
                for o in self.eff_op(i).operands() {
                    if int_to_final[o].is_some() {
                        d[o] = true;
                    }
                }
            }
        }
        let mut dirty = vec![false; final_len];
        for i in 0..total {
            if let Some(nf) = int_to_final[i] {
                if d[i] {
                    dirty[nf] = true;
                }
            }
        }
        let old_to_new = int_to_final[..nb].to_vec();
        DirtySet { old_to_new, dirty }
    }
}

/// Kahn topological order over a node list — byte-for-byte the same
/// algorithm the wholesale algebraic rewrite sorts with (sorted initial
/// zero-indegree queue, FIFO walk), so a resorting patch renumbers
/// exactly like the pass it replaces.  Errs (instead of asserting) on a
/// cycle, keeping `apply` atomic.
fn kahn_order(nodes: &[Node]) -> Result<Vec<NodeId>> {
    let n = nodes.len();
    let mut indeg = vec![0usize; n];
    let mut users: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for (id, node) in nodes.iter().enumerate() {
        let mut ops = node.op.operands();
        ops.sort_unstable();
        ops.dedup();
        indeg[id] = ops.len();
        for o in ops {
            users[o].push(id);
        }
    }
    let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
    queue.sort_unstable();
    let mut qi = 0;
    while qi < queue.len() {
        let id = queue[qi];
        qi += 1;
        for &u in &users[id] {
            indeg[u] -= 1;
            if indeg[u] == 0 {
                queue.push(u);
            }
        }
    }
    if queue.len() != n {
        bail!("patch introduces a cycle: only {} of {n} nodes sortable", queue.len());
    }
    Ok(queue)
}

/// Liveness-based compaction — the same algorithm as `rewrite::dce`
/// (outputs-rooted liveness, all `Input` nodes kept, order-preserving
/// remap) but also returning the old→new id map for dirty tracking.
fn prune_graph(g: &Graph) -> (Graph, Vec<Option<NodeId>>) {
    let mut live = vec![false; g.nodes.len()];
    let mut stack: Vec<usize> = g.outputs.clone();
    while let Some(id) = stack.pop() {
        if live[id] {
            continue;
        }
        live[id] = true;
        stack.extend(g.nodes[id].op.operands());
    }
    for (i, n) in g.nodes.iter().enumerate() {
        if matches!(n.op, Op::Input { .. }) {
            live[i] = true;
        }
    }
    let mut remap: Vec<Option<NodeId>> = vec![None; g.nodes.len()];
    let mut nodes = Vec::new();
    for (i, n) in g.nodes.iter().enumerate() {
        if live[i] {
            remap[i] = Some(nodes.len());
            nodes.push(Node {
                op: n.op.map_operands(|o| remap[o].expect("live operand")),
                shape: n.shape.clone(),
            });
        }
    }
    let out = Graph {
        name: g.name.clone(),
        nodes,
        input_shapes: g.input_shapes.clone(),
        outputs: g.outputs.iter().map(|&o| remap[o].expect("live output")).collect(),
    };
    (out, remap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::{BinaryKind, UnaryKind};
    use crate::tensor::Shape;

    fn chain() -> Graph {
        let mut b = GraphBuilder::new("p");
        let x = b.input(Shape::of(&[4, 4]));
        let r = b.unary(UnaryKind::Relu, x);
        let t = b.unary(UnaryKind::Tanh, r);
        b.finish(vec![t])
    }

    #[test]
    fn empty_patch_is_identity() {
        let g = chain();
        let (out, dirty) = GraphPatch::new(&g).apply().unwrap();
        assert_eq!(out, g);
        assert_eq!(out.render(), g.render());
        assert_eq!(dirty.count(), 0);
        assert_eq!(dirty.old_to_new, (0..g.len()).map(Some).collect::<Vec<_>>());
    }

    #[test]
    fn add_and_rewire_output() {
        let g = chain();
        let mut p = GraphPatch::new(&g);
        p.prune();
        let n = p.add(Op::Unary { kind: UnaryKind::Neg, input: 2 }).unwrap();
        p.rewire_output(0, n).unwrap();
        let (out, dirty) = p.apply().unwrap();
        assert_eq!(out.len(), 4);
        assert!(matches!(out.nodes[3].op, Op::Unary { kind: UnaryKind::Neg, .. }));
        assert_eq!(out.outputs, vec![3]);
        // the added node and its operand (new user) are dirty; the tanh
        // also lost its output slot, so it is dirty twice over
        assert!(dirty.is_dirty(3) && dirty.is_dirty(2));
        assert!(!dirty.is_dirty(0) && !dirty.is_dirty(1));
    }

    #[test]
    fn redirect_prunes_and_marks_target() {
        let g = chain();
        let mut p = GraphPatch::new(&g);
        p.prune();
        // bypass the relu: tanh reads x directly
        p.redirect(1, 0).unwrap();
        let (out, dirty) = p.apply().unwrap();
        assert_eq!(out.len(), 2); // x, tanh — relu dead and never materialized
        assert_eq!(dirty.old_to_new, vec![Some(0), None, Some(1)]);
        assert!(dirty.is_dirty(0), "redirect target gained a user");
        assert!(dirty.is_dirty(1), "user's operand identity changed");
    }

    #[test]
    fn conflicting_edits_name_both_ids() {
        let g = chain();
        let mut p = GraphPatch::new(&g);
        p.replace(1, Op::Unary { kind: UnaryKind::Neg, input: 0 }).unwrap();
        let err = p.redirect(1, 0).unwrap_err().to_string();
        assert!(err.contains("%1") && err.contains("%0"), "{err}");
        let mut q = GraphPatch::new(&g);
        q.redirect(1, 0).unwrap();
        let err = q.replace(1, Op::Unary { kind: UnaryKind::Neg, input: 0 }).unwrap_err().to_string();
        assert!(err.contains("%1") && err.contains("%0"), "{err}");
        let err = q.redirect(1, 2).unwrap_err().to_string();
        assert!(err.contains("%1") && err.contains("%2"), "{err}");
    }

    #[test]
    fn redirect_cycle_rejected() {
        let g = chain();
        let mut p = GraphPatch::new(&g);
        p.redirect(2, 1).unwrap();
        let err = p.redirect(1, 2).unwrap_err().to_string();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn shape_changing_redirect_rejected() {
        let mut b = GraphBuilder::new("s");
        let x = b.input(Shape::of(&[4, 4]));
        let y = b.input(Shape::of(&[2, 2]));
        let r = b.unary(UnaryKind::Relu, x);
        let _ = y;
        let g = b.finish(vec![r]);
        let mut p = GraphPatch::new(&g);
        assert!(p.redirect(2, 1).is_err());
    }

    #[test]
    fn invalid_result_is_rejected_atomically() {
        let g = chain();
        let mut p = GraphPatch::new(&g);
        // empty output list fails validation on apply
        p.set_outputs(vec![]).unwrap();
        assert!(p.apply().is_err());
    }

    #[test]
    fn closures_walk_both_directions() {
        let mut b = GraphBuilder::new("c");
        let x = b.input(Shape::of(&[4]));
        let r = b.unary(UnaryKind::Relu, x);
        let t = b.unary(UnaryKind::Tanh, r);
        let u = b.binary(BinaryKind::Add, t, r);
        let g = b.finish(vec![u]);
        let mut p = GraphPatch::new(&g);
        p.replace(2, Op::Unary { kind: UnaryKind::Neg, input: 1 }).unwrap();
        let (out, dirty) = p.apply().unwrap();
        let fwd = dirty.forward_closure(&out);
        assert!(fwd[2] && fwd[3], "replacement and its user are downstream-dirty");
        assert!(!fwd[0], "input upstream of the edit is not in the forward closure");
        let bwd = dirty.backward_closure(&out);
        assert!(bwd[2] && bwd[1] && bwd[0], "upstream cone reaches the inputs");
    }
}
