//! Seeded random KIR graph generation — the differential-fuzzing and
//! synthetic-workload backbone of the conformance subsystem.
//!
//! [`graph`] turns a `u64` seed into a well-typed [`Graph`] drawn from
//! the full op vocabulary (every [`Op`] variant, every unary / binary /
//! reduce kind) over small static shapes, including deliberate
//! injections of the motifs the rewrite passes fire on (the §7.4
//! `sum₁∘(matmul+bias)` chain and the §7.3 singleton-reduce /
//! `sub(a,a)` collapse).  The same seed always produces the same graph,
//! so a failing case is reproducible from one integer.
//!
//! [`equivalent`] is the differential oracle: a rewritten graph must
//! keep validator invariants and interpreter semantics on seeded
//! inputs.  [`shrink`] greedily minimizes a failing graph (output
//! pruning + same-shape node bypassing) so a fuzz failure prints as a
//! few-line repro instead of a 20-node soup.

use super::graph::{infer_shape, Graph, GraphBuilder, NodeId};
use super::interp;
use super::op::{BinaryKind, Op, ReduceKind, UnaryKind};
use super::patch::GraphPatch;
use super::rewrite::dce_wholesale;
use super::validate::validate;
use crate::tensor::{Shape, Tensor};
use crate::util::rng::Pcg;

/// Generation knobs.  The defaults keep graphs small enough that the
/// interpreter prices thousands of them per second while still covering
/// every op and shape class.
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Compute ops appended after the initial inputs.
    pub max_ops: usize,
    /// Largest sampled dimension (smallest is 1, drawn occasionally to
    /// exercise singleton-axis paths).
    pub dim_max: usize,
    /// Probability that a step emits a rewrite-trigger motif instead of
    /// a single random op.
    pub motif_chance: f64,
    /// Cap on declared graph inputs; once reached, fresh operands come
    /// from `ConstFill` instead.
    pub max_inputs: usize,
}

impl Default for FuzzConfig {
    fn default() -> FuzzConfig {
        FuzzConfig {
            max_ops: 12,
            dim_max: 6,
            motif_chance: 0.3,
            max_inputs: 10,
        }
    }
}

/// Generate the seeded graph with default knobs.
pub fn graph(seed: u64) -> Graph {
    graph_with(seed, &FuzzConfig::default())
}

/// Unary kinds safe for the DAG motifs: bounded or polynomial, so the
/// duplicated chains don't drag the finite-evaluation rate down (exp
/// can overflow, sqrt NaNs on negatives).
const DAG_UNARIES: &[UnaryKind] = &[
    UnaryKind::Relu,
    UnaryKind::Sigmoid,
    UnaryKind::Gelu,
    UnaryKind::Tanh,
    UnaryKind::Neg,
    UnaryKind::Square,
];

/// Generate the seeded graph with explicit knobs.
pub fn graph_with(seed: u64, cfg: &FuzzConfig) -> Graph {
    let mut rng = Pcg::new(seed, 0xF0_77_ED);
    let mut gen = Gen {
        b: GraphBuilder::new(&format!("fuzz_{seed:x}")),
        shapes: Vec::new(),
        rng: &mut rng,
        cfg,
        n_inputs: 0,
    };
    // 1–3 starting inputs, rank 2 biased (the dominant shape class)
    let n_start = 1 + gen.rng.below(3) as usize;
    for _ in 0..n_start {
        let shape = gen.random_shape();
        gen.fresh(shape);
    }
    let n_ops = 1 + gen.rng.below(cfg.max_ops as u32) as usize;
    for _ in 0..n_ops {
        if gen.rng.chance(cfg.motif_chance) {
            gen.motif();
        } else {
            gen.step();
        }
    }
    // outputs: the final node plus up to two random earlier ones
    let mut outputs = vec![gen.shapes.len() - 1];
    for _ in 0..gen.rng.below(3) {
        outputs.push(gen.any());
    }
    outputs.sort_unstable();
    outputs.dedup();
    gen.b.finish(outputs)
}

/// Seeded evaluation inputs for a fuzzed graph (small values keep
/// transcendental chains finite for the overwhelming majority of
/// seeds; callers skip the non-finite remainder).
pub fn inputs(g: &Graph, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg::new(seed, crate::util::rng::fnv1a(g.name.as_bytes()));
    g.input_shapes
        .iter()
        .map(|s| Tensor::randn(s.clone(), &mut rng, 0.4))
        .collect()
}

/// The differential oracle: does `rewritten` keep `original`'s
/// validator invariants and interpreter semantics on `ins`?  Returns a
/// description of the first divergence.  Output positions whose
/// reference value is non-finite carry no numeric claim (rewrites may
/// legally reassociate them) but must still match in arity and shape.
pub fn equivalent(
    original: &Graph,
    rewritten: &Graph,
    ins: &[Tensor],
    rtol: f32,
    atol: f32,
) -> Result<(), String> {
    if let Err(e) = validate(rewritten) {
        return Err(format!("rewritten graph fails validation: {e}"));
    }
    let want = match interp::eval(original, ins) {
        Ok(w) => w,
        Err(e) => return Err(format!("original graph failed to evaluate: {e}")),
    };
    let got = match interp::eval(rewritten, ins) {
        Ok(g) => g,
        Err(e) => return Err(format!("rewritten graph failed to evaluate: {e}")),
    };
    if got.len() != want.len() {
        return Err(format!(
            "output arity changed: {} -> {}",
            want.len(),
            got.len()
        ));
    }
    for (i, (gt, wt)) in got.iter().zip(&want).enumerate() {
        if gt.shape != wt.shape {
            return Err(format!("output {i} shape changed: {} -> {}", wt.shape, gt.shape));
        }
        if wt.data.iter().any(|v| !v.is_finite()) {
            continue;
        }
        if !gt.allclose(wt, rtol, atol) {
            return Err(format!(
                "output {i} numerics diverge: max |diff| = {:.6}",
                gt.max_abs_diff(wt)
            ));
        }
    }
    Ok(())
}

/// How much work a shrink run did — the regression handle for the
/// shrinker's complexity (the clone-based shrinker was quadratic in
/// candidate construction; the patch-based one only materializes each
/// candidate's live cone).
#[derive(Debug, Clone, Copy, Default)]
pub struct ShrinkStats {
    /// Candidate graphs constructed and tested.
    pub attempts: usize,
    /// Candidates accepted (strictly smaller and still failing).
    pub accepted: usize,
    /// Total nodes materialized across all candidates.
    pub materialized_nodes: usize,
}

/// Greedily minimize a failing graph while `still_fails` holds.
///
/// Two reductions:
/// 1. try narrowing to each single output in turn, keeping the first
///    one that still reproduces the failure;
/// 2. bypass nodes to a fixpoint: redirect a node's users to a
///    same-shaped operand and DCE it away.
///
/// Both preserve well-typedness, so the shrunk graph is always a valid
/// repro for the same predicate.  Candidates are built as
/// [`GraphPatch`]es against the current graph — dead nodes are never
/// cloned into a candidate, which keeps large-graph shrinks near-linear
/// where the old clone-per-candidate loop was quadratic.  Visit order
/// is identical to [`shrink_wholesale`], so both produce the same
/// repro.
pub fn shrink(g: &Graph, still_fails: &dyn Fn(&Graph) -> bool) -> Graph {
    shrink_with_stats(g, still_fails).0
}

/// [`shrink`] with work statistics.
pub fn shrink_with_stats(
    g: &Graph,
    still_fails: &dyn Fn(&Graph) -> bool,
) -> (Graph, ShrinkStats) {
    let mut stats = ShrinkStats::default();
    let mut cur = g.clone();
    // 1. output minimization: a single output is the best repro
    if cur.outputs.len() > 1 {
        for pos in 0..cur.outputs.len() {
            let o = cur.outputs[pos];
            let mut p = GraphPatch::new(&cur);
            p.prune();
            p.set_outputs(vec![o]).expect("shrink: output subset stays valid");
            let (cand, _) = p.apply().expect("shrink: output-narrowing patch applies");
            stats.attempts += 1;
            stats.materialized_nodes += cand.len();
            if cand.len() < cur.len() && still_fails(&cand) {
                stats.accepted += 1;
                cur = cand;
                break;
            }
        }
    }
    // 2. node bypassing to a fixpoint
    loop {
        let mut changed = false;
        for id in (0..cur.nodes.len()).rev() {
            if matches!(cur.nodes[id].op, Op::Input { .. }) {
                continue;
            }
            let shape = cur.nodes[id].shape.clone();
            for o in cur.nodes[id].op.operands() {
                if cur.nodes[o].shape != shape {
                    continue;
                }
                let mut p = GraphPatch::new(&cur);
                p.prune();
                p.redirect(id, o).expect("shrink: same-shape bypass stages");
                let (cand, _) = p.apply().expect("shrink: bypass patch applies");
                stats.attempts += 1;
                stats.materialized_nodes += cand.len();
                if cand.len() < cur.len() && still_fails(&cand) {
                    stats.accepted += 1;
                    cur = cand;
                    changed = true;
                    break;
                }
            }
            if changed {
                break;
            }
        }
        if !changed {
            break;
        }
    }
    (cur, stats)
}

/// The original clone-per-candidate shrinker, kept as the differential
/// reference: [`shrink`] must produce the same repro with less work.
pub fn shrink_wholesale(g: &Graph, still_fails: &dyn Fn(&Graph) -> bool) -> Graph {
    let mut cur = g.clone();
    // 1. output minimization: a single output is the best repro
    if cur.outputs.len() > 1 {
        for &o in cur.outputs.clone().iter() {
            let mut cand = cur.clone();
            cand.outputs = vec![o];
            let cand = dce_wholesale(&cand);
            if cand.len() < cur.len() && still_fails(&cand) {
                cur = cand;
                break;
            }
        }
    }
    // 2. node bypassing to a fixpoint
    loop {
        let mut changed = false;
        for id in (0..cur.nodes.len()).rev() {
            if matches!(cur.nodes[id].op, Op::Input { .. }) {
                continue;
            }
            let shape = cur.nodes[id].shape.clone();
            for o in cur.nodes[id].op.operands() {
                if cur.nodes[o].shape != shape {
                    continue;
                }
                let cand = bypass(&cur, id, o);
                if cand.len() < cur.len() && still_fails(&cand) {
                    cur = cand;
                    changed = true;
                    break;
                }
            }
            if changed {
                break;
            }
        }
        if !changed {
            break;
        }
    }
    cur
}

/// Redirect every use of `from` (including outputs) to `to` and prune.
/// Caller guarantees the two nodes share a shape.
fn bypass(g: &Graph, from: NodeId, to: NodeId) -> Graph {
    let mut out = g.clone();
    for n in out.nodes.iter_mut() {
        n.op = n.op.map_operands(|o| if o == from { to } else { o });
    }
    for o in out.outputs.iter_mut() {
        if *o == from {
            *o = to;
        }
    }
    dce_wholesale(&out)
}

// ---------------------------------------------------------------------------

struct Gen<'a> {
    b: GraphBuilder,
    /// Shapes mirroring the builder's node list (the builder keeps its
    /// node list private; ids stay aligned because both only append).
    shapes: Vec<Shape>,
    rng: &'a mut Pcg,
    cfg: &'a FuzzConfig,
    n_inputs: usize,
}

impl Gen<'_> {
    /// One random dimension; occasionally 1 to exercise singleton axes.
    fn dim(&mut self) -> usize {
        if self.rng.chance(0.12) {
            1
        } else {
            self.rng.range_i64(2, self.cfg.dim_max as i64) as usize
        }
    }

    fn random_shape(&mut self) -> Shape {
        match self.rng.below(10) {
            0 => Shape::of(&[self.dim()]),
            1..=6 => {
                let (m, n) = (self.dim(), self.dim());
                Shape::of(&[m, n])
            }
            _ => {
                let n = 1 + self.rng.below(2) as usize;
                let c = 1 + self.rng.below(3) as usize;
                let hw = self.rng.range_i64(3, self.cfg.dim_max as i64) as usize;
                Shape::of(&[n, c, hw, hw])
            }
        }
    }

    /// A fresh leaf of the given shape: a new graph input while the
    /// input budget lasts, a constant fill afterwards.
    fn fresh(&mut self, shape: Shape) -> NodeId {
        if self.n_inputs < self.cfg.max_inputs {
            self.n_inputs += 1;
            self.shapes.push(shape.clone());
            self.b.input(shape)
        } else {
            let value = self.rng.range_f64(-1.5, 1.5) as f32;
            self.push(Op::ConstFill { value, shape })
        }
    }

    /// Push a non-Input op, mirroring the builder's shape inference.
    fn push(&mut self, op: Op) -> NodeId {
        let shapes = &self.shapes;
        let shape = infer_shape(&op, &|i| shapes[i].clone(), &[])
            .unwrap_or_else(|e| panic!("fuzz generator built an ill-typed {op:?}: {e}"));
        let id = self.b.push(op);
        self.shapes.push(shape);
        id
    }

    fn any(&mut self) -> NodeId {
        self.rng.below(self.shapes.len() as u32) as usize
    }

    fn nodes_where(&self, pred: impl Fn(&Shape) -> bool) -> Vec<NodeId> {
        self.shapes
            .iter()
            .enumerate()
            .filter(|(_, s)| pred(s))
            .map(|(i, _)| i)
            .collect()
    }

    fn pick(&mut self, ids: &[NodeId]) -> NodeId {
        ids[self.rng.below(ids.len() as u32) as usize]
    }

    /// A rank-2 node, minting an input if none exists yet.
    fn rank2(&mut self) -> NodeId {
        let cands = self.nodes_where(|s| s.rank() == 2);
        if cands.is_empty() {
            let (m, n) = (self.dim(), self.dim());
            self.fresh(Shape::of(&[m, n]))
        } else {
            self.pick(&cands)
        }
    }

    /// A rank-4 (NCHW, spatial ≥ 3) node, minting an input if needed.
    fn rank4(&mut self) -> NodeId {
        let cands = self.nodes_where(|s| s.rank() == 4 && s.dim(2) >= 3 && s.dim(3) >= 3);
        if cands.is_empty() {
            let n = 1 + self.rng.below(2) as usize;
            let c = 1 + self.rng.below(3) as usize;
            let hw = self.rng.range_i64(3, self.cfg.dim_max as i64) as usize;
            self.fresh(Shape::of(&[n, c, hw, hw]))
        } else {
            self.pick(&cands)
        }
    }

    /// One random op from the full vocabulary.
    fn step(&mut self) {
        match self.rng.below(14) {
            0 | 1 => {
                let kind = *self.rng.choose(&UnaryKind::ALL);
                let mut x = self.any();
                if kind == UnaryKind::Sqrt {
                    // sqrt of a randn value is NaN half the time; square
                    // first so sqrt coverage doesn't poison the suite
                    x = self.push(Op::Unary { kind: UnaryKind::Square, input: x });
                }
                self.push(Op::Unary { kind, input: x });
            }
            2 | 3 => self.binary(),
            4 => {
                self.matmul();
            }
            5 => {
                let x = self.rank2();
                self.push(Op::Transpose2 { input: x });
            }
            6 => self.reduce(),
            7 => {
                let cands = self.nodes_where(|s| s.rank() >= 1);
                if let Some(&x) = cands.first() {
                    let x = if cands.len() > 1 { self.pick(&cands) } else { x };
                    self.push(Op::Softmax { input: x });
                }
            }
            8 => self.layernorm(),
            9 => self.attention(),
            10 => self.conv(),
            11 => self.pool(),
            12 => self.concat(),
            _ => self.reshape(),
        }
    }

    fn binary(&mut self) {
        let a = self.any();
        let kind = *self
            .rng
            .choose(&[BinaryKind::Add, BinaryKind::Sub, BinaryKind::Mul, BinaryKind::Max, BinaryKind::Div]);
        let rhs = if kind == BinaryKind::Div {
            // denominators bounded away from zero keep most seeds finite
            let value = self.rng.range_f64(0.6, 1.8) as f32;
            let shape = self.shapes[a].clone();
            self.push(Op::ConstFill { value, shape })
        } else {
            match self.rng.below(3) {
                0 => {
                    // same-shape partner; `a` itself qualifies, which
                    // also mints sub(a,a) — the §7.3 zero collapse
                    let shape = self.shapes[a].clone();
                    let mates = self.nodes_where(|s| *s == shape);
                    self.pick(&mates)
                }
                1 if self.shapes[a].rank() >= 1 => {
                    // row-broadcast vector over the last axis
                    let f = self.shapes[a].dim(self.shapes[a].rank() - 1);
                    self.fresh(Shape::of(&[f]))
                }
                _ => {
                    let value = self.rng.range_f64(-1.5, 1.5) as f32;
                    self.push(Op::ConstFill { value, shape: Shape::scalar() })
                }
            }
        };
        self.push(Op::Binary { kind, lhs: a, rhs });
    }

    fn matmul(&mut self) -> NodeId {
        let x = self.rank2();
        let k = self.shapes[x].dim(1);
        let mates = self.nodes_where(|s| s.rank() == 2 && s.dim(0) == k);
        let w = if mates.is_empty() || self.rng.chance(0.5) {
            let n = self.dim();
            self.fresh(Shape::of(&[k, n]))
        } else {
            self.pick(&mates)
        };
        self.push(Op::Matmul { lhs: x, rhs: w })
    }

    fn reduce(&mut self) {
        let cands = self.nodes_where(|s| s.rank() >= 1);
        if cands.is_empty() {
            return;
        }
        let x = self.pick(&cands);
        let axis = self.rng.below(self.shapes[x].rank() as u32) as usize;
        let kind = *self.rng.choose(&[
            ReduceKind::Sum,
            ReduceKind::Max,
            ReduceKind::Mean,
            ReduceKind::LogSumExp,
        ]);
        self.push(Op::Reduce { kind, axis, input: x });
    }

    fn layernorm(&mut self) {
        let cands = self.nodes_where(|s| s.rank() >= 1);
        if cands.is_empty() {
            return;
        }
        let x = self.pick(&cands);
        let f = self.shapes[x].dim(self.shapes[x].rank() - 1);
        let gamma = self.fresh(Shape::of(&[f]));
        let beta = self.fresh(Shape::of(&[f]));
        self.push(Op::Layernorm { input: x, gamma, beta });
    }

    fn attention(&mut self) {
        let q = self.rank2();
        let d = self.shapes[q].dim(1);
        let sk = self.dim();
        let dv = self.dim();
        let k = self.fresh(Shape::of(&[sk, d]));
        let v = self.fresh(Shape::of(&[sk, dv]));
        self.push(Op::Attention { q, k, v });
    }

    fn conv(&mut self) {
        let x = self.rank4();
        let (c, h, w) = (self.shapes[x].dim(1), self.shapes[x].dim(2), self.shapes[x].dim(3));
        let kk = 1 + self.rng.below(h.min(w).min(3) as u32) as usize;
        let stride = 1 + self.rng.below(2) as usize;
        let padding = self.rng.below(2) as usize;
        if self.rng.chance(0.7) {
            let o = 1 + self.rng.below(4) as usize;
            let weight = self.fresh(Shape::of(&[o, c, kk, kk]));
            self.push(Op::Conv2d { input: x, weight, stride, padding });
        } else {
            let weight = self.fresh(Shape::of(&[c, 1, kk, kk]));
            self.push(Op::DepthwiseConv2d { input: x, weight, stride, padding });
        }
    }

    fn pool(&mut self) {
        let x = self.rank4();
        match self.rng.below(3) {
            0 => {
                self.push(Op::GlobalAvgPool { input: x });
            }
            which => {
                let (h, w) = (self.shapes[x].dim(2), self.shapes[x].dim(3));
                let k = 1 + self.rng.below(h.min(w).min(3) as u32) as usize;
                let stride = 1 + self.rng.below(2) as usize;
                if which == 1 {
                    self.push(Op::MaxPool2d { input: x, k, stride });
                } else {
                    self.push(Op::AvgPool2d { input: x, k, stride });
                }
            }
        }
    }

    fn concat(&mut self) {
        let cands = self.nodes_where(|s| s.rank() >= 1);
        if cands.is_empty() {
            return;
        }
        let x = self.pick(&cands);
        let shape = self.shapes[x].clone();
        let mates = self.nodes_where(|s| *s == shape);
        let mut inputs = vec![x, self.pick(&mates)];
        if self.rng.chance(0.3) {
            inputs.push(self.pick(&mates));
        }
        let axis = self.rng.below(shape.rank() as u32) as usize;
        self.push(Op::Concat { inputs, axis });
    }

    fn reshape(&mut self) {
        let x = self.any();
        let numel = self.shapes[x].numel();
        let shape = self.factorize(numel);
        self.push(Op::Reshape { input: x, shape });
    }

    /// Split `numel` into 1–3 factors (a valid reshape target).
    fn factorize(&mut self, numel: usize) -> Shape {
        let mut dims = Vec::new();
        let mut rem = numel.max(1);
        let parts = 1 + self.rng.below(3) as usize;
        for _ in 1..parts {
            let divisors: Vec<usize> = (1..=rem).filter(|d| rem % d == 0).collect();
            let d = *self.rng.choose(&divisors);
            dims.push(d);
            rem /= d;
        }
        dims.push(rem);
        Shape(dims)
    }

    /// Emit a rewrite-trigger motif instead of a single op.
    fn motif(&mut self) {
        match self.rng.below(4) {
            0 => {
                // §7.4: sum over columns of (x@W [+ bias]) — the algebraic
                // matmul→matvec reduction's exact match shape
                let mm = self.matmul();
                let fed = if self.rng.chance(0.6) {
                    let n = self.shapes[mm].dim(1);
                    let bias = self.fresh(Shape::of(&[n]));
                    self.push(Op::Binary { kind: BinaryKind::Add, lhs: mm, rhs: bias })
                } else {
                    mm
                };
                self.push(Op::Reduce { kind: ReduceKind::Sum, axis: 1, input: fed });
            }
            1 => {
                // §7.3: max₁ → mean over the now-singleton axis → sub = 0
                let x = self.rank2();
                let mx = self.push(Op::Reduce { kind: ReduceKind::Max, axis: 1, input: x });
                let mean = self.push(Op::Reduce { kind: ReduceKind::Mean, axis: 1, input: mx });
                let sub = self.push(Op::Binary { kind: BinaryKind::Sub, lhs: mx, rhs: mean });
                if self.rng.chance(0.5) {
                    self.push(Op::Unary { kind: UnaryKind::Gelu, input: sub });
                }
            }
            2 => {
                // DAG fan-out join: one value feeding two divergent
                // chains, rejoined by a binary — the cross-kernel
                // dataflow shape whole-model (level-4) graphs are made
                // of, which fusion must not duplicate
                let x = self.rank2();
                let ka = *self.rng.choose(DAG_UNARIES);
                let kb = *self.rng.choose(DAG_UNARIES);
                let a = self.push(Op::Unary { kind: ka, input: x });
                let b = self.push(Op::Unary { kind: kb, input: x });
                let kind = if self.rng.chance(0.5) { BinaryKind::Mul } else { BinaryKind::Add };
                self.push(Op::Binary { kind, lhs: a, rhs: b });
            }
            _ => {
                // shared subexpression across a kernel boundary: the
                // same op emitted twice from the same operand (CSE
                // fodder — `cse::eliminate` must merge the twins)
                let x = self.rank2();
                let k = *self.rng.choose(DAG_UNARIES);
                let t1 = self.push(Op::Unary { kind: k, input: x });
                let t2 = self.push(Op::Unary { kind: k, input: x });
                self.push(Op::Binary { kind: BinaryKind::Add, lhs: t1, rhs: t2 });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::rewrite::{algebraic, constant_fold};
    use std::collections::BTreeSet;

    #[test]
    fn generator_is_deterministic() {
        for seed in 0..20 {
            let a = graph(seed);
            let b = graph(seed);
            assert_eq!(a, b, "seed {seed} not reproducible");
        }
    }

    #[test]
    fn generated_graphs_always_validate() {
        for seed in 0..300 {
            let g = graph(seed);
            validate(&g).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{}", g.render()));
            assert!(!g.outputs.is_empty());
        }
    }

    #[test]
    fn generator_covers_the_op_vocabulary() {
        let mut seen: BTreeSet<String> = BTreeSet::new();
        for seed in 0..1000 {
            for n in graph(seed).nodes.iter() {
                let m = n.op.mnemonic();
                // normalize reduce_<kind><axis> to reduce_<kind>
                let fam = if let Some(rest) = m.strip_prefix("reduce_") {
                    format!("reduce_{}", rest.trim_end_matches(|c: char| c.is_ascii_digit()))
                } else {
                    m
                };
                seen.insert(fam);
            }
        }
        for want in [
            "const", "matmul", "transpose", "softmax", "layernorm", "attention", "conv2d",
            "dwconv2d", "maxpool2d", "avgpool2d", "gavgpool", "concat", "reshape",
            "reduce_sum", "reduce_max", "reduce_mean", "reduce_logsumexp",
            "relu", "sigmoid", "swish", "gelu", "tanh", "exp", "neg", "square", "sqrt",
            "add", "sub", "mul", "div", "max", "input0",
        ] {
            assert!(seen.contains(want), "op family {want:?} never generated; saw {seen:?}");
        }
    }

    #[test]
    fn most_seeds_evaluate_finite() {
        let mut finite = 0;
        let total = 200;
        for seed in 0..total {
            let g = graph(seed);
            let ins = inputs(&g, seed);
            if let Ok(out) = interp::eval(&g, &ins) {
                if out.iter().all(|t| t.data.iter().all(|v| v.is_finite())) {
                    finite += 1;
                }
            }
        }
        assert!(finite * 5 >= total * 4, "only {finite}/{total} seeds finite");
    }

    #[test]
    fn motifs_reach_the_rewrite_passes() {
        let mut algebraic_hits = 0;
        let mut constant_hits = 0;
        for seed in 0..300 {
            let g = graph(seed);
            if algebraic::count_opportunities(&g) > 0 {
                algebraic_hits += 1;
            }
            if constant_fold::fold(&g).len() < g.len() {
                constant_hits += 1;
            }
        }
        assert!(algebraic_hits >= 20, "algebraic motif too rare: {algebraic_hits}/300");
        assert!(constant_hits >= 20, "constant-fold motif too rare: {constant_hits}/300");
    }

    #[test]
    fn dag_motifs_cover_fan_out_and_shared_subexpressions() {
        // over 1,000 seeds the generator must routinely emit (a) nodes
        // with fan-out >= 2 feeding a rejoining binary and (b) twin
        // subexpressions that cse::eliminate can merge
        let mut fan_out_graphs = 0;
        let mut cse_graphs = 0;
        let total = 1000;
        for seed in 0..total {
            let g = graph(seed);
            let uses = g.use_counts();
            let has_fan_out = g.nodes.iter().enumerate().any(|(i, n)| {
                !matches!(n.op, Op::Input { .. }) && uses[i] >= 2
            });
            if has_fan_out {
                fan_out_graphs += 1;
            }
            if crate::kir::rewrite::cse::eliminate(&g).len() < g.len() {
                cse_graphs += 1;
            }
        }
        assert!(fan_out_graphs >= 100, "fan-out joins too rare: {fan_out_graphs}/{total}");
        assert!(cse_graphs >= 50, "shared subexpressions too rare: {cse_graphs}/{total}");
    }

    #[test]
    fn dag_motif_graphs_stay_sound() {
        // the motif change must not cost validity or determinism at
        // the 1,000-seed scale the coverage assertions run at
        for seed in 0..1000 {
            let g = graph(seed);
            validate(&g).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{}", g.render()));
            assert_eq!(g, graph(seed), "seed {seed} not reproducible");
        }
    }

    #[test]
    fn equivalent_accepts_identity_and_flags_corruption() {
        let g = graph(7);
        let ins = inputs(&g, 7);
        assert!(equivalent(&g, &g, &ins, 1e-6, 1e-6).is_ok());
        let mut broken = g.clone();
        broken.outputs = vec![broken.nodes.len() + 5];
        let err = equivalent(&g, &broken, &ins, 1e-6, 1e-6).unwrap_err();
        assert!(err.contains("validation"), "{err}");
    }

    #[test]
    fn shrink_minimizes_while_preserving_the_failure() {
        // predicate: graph still contains a matmul node
        let seed = (0..500)
            .find(|&s| graph(s).nodes.iter().any(|n| matches!(n.op, Op::Matmul { .. })))
            .expect("some seed contains a matmul");
        let g = graph(seed);
        let has_matmul =
            |g: &Graph| g.nodes.iter().any(|n| matches!(n.op, Op::Matmul { .. }));
        let min = shrink(&g, &has_matmul);
        assert!(has_matmul(&min), "shrink lost the failure");
        assert!(min.len() <= g.len());
        validate(&min).unwrap();
    }

    #[test]
    fn patch_shrink_matches_wholesale_shrink() {
        // identical visit order ⇒ identical repro, on matmul-bearing
        // seeds (predicate mirrors the conformance harness's usage)
        let has_matmul =
            |g: &Graph| g.nodes.iter().any(|n| matches!(n.op, Op::Matmul { .. }));
        let mut tested = 0;
        for seed in 0..200 {
            let g = graph(seed);
            if !has_matmul(&g) {
                continue;
            }
            tested += 1;
            let (min_p, stats) = shrink_with_stats(&g, &has_matmul);
            let min_w = shrink_wholesale(&g, &has_matmul);
            assert_eq!(min_p, min_w, "seed {seed}: patch shrink diverges from wholesale");
            assert!(min_p.len() <= min_w.len());
            assert!(stats.attempts > 0 || g.len() == min_p.len());
        }
        assert!(tested >= 20, "only {tested} matmul seeds in range");
    }

    #[test]
    fn shrunk_graph_keeps_input_interface() {
        let g = graph(11);
        let min = shrink(&g, &|_| true);
        assert_eq!(min.input_shapes, g.input_shapes);
    }
}
