//! KIR — the Kernel IR that synthesized programs are expressed in.
//!
//! A candidate program is `(Graph, Schedule, defects)`: the graph is the
//! computation (possibly rewritten by the generation agent — fusion
//! discovery, constant-output collapse, algebraic reduction), the
//! schedule maps it onto a platform, and defects are the concrete
//! errors an imperfect synthesizer injects (they genuinely fail
//! validation, lowering, or numerics downstream — see `agents`).
//!
//! - [`op`] / [`graph`] — typed tensor-op graph, eager shape inference.
//! - [`patch`] — staged incremental edits ([`GraphPatch`]) with
//!   dirty-region tracking ([`DirtySet`]); the rewrite passes emit
//!   patches and keep their whole-graph entry points as thin wrappers.
//! - [`validate`] — structural checks; failure = *compilation failure*.
//! - [`interp`] — reference evaluation via `tensor::ops`.
//! - [`rewrite`] — fusion discovery, constant folding (§7.3 invariance
//!   exploitation), algebraic reduction (§7.4 matmul→matvec), CSE.
//! - [`fuzz`] — seeded random graph generation, the differential
//!   oracle, and failure shrinking (conformance subsystem).

pub mod op;
pub mod graph;
pub mod patch;
pub mod validate;
pub mod interp;
pub mod rewrite;
pub mod fuzz;

pub use graph::{Graph, GraphBuilder, Node, NodeId};
pub use patch::{DirtySet, GraphPatch};
pub use op::{BinaryKind, Op, ReduceKind, UnaryKind};
