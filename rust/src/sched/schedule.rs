//! Schedules: the tunable mapping from a KIR graph to kernel launches.
//!
//! A schedule bundles the decisions the paper's case studies surface:
//! - **fusion depth** — how many of the graph's fusion opportunities are
//!   taken (§5.1's kernel fusion; 0 = eager, all = fully fused);
//! - **tile** — matmul/conv threadblock tiling (bm, bn, bk);
//! - **elements-per-thread** — §7.2's Swish optimization (1–16);
//! - **threadgroup size** — occupancy lever (32–1024, warp multiples);
//! - **fast_math** — `fast::exp`-style intrinsics (§7.2), trading
//!   ~1e-3 relative error for transcendental throughput;
//! - **use_graphs** — CUDA-graphs launch consolidation (§5.1: "CUDA
//!   Graphs incorporation that allows consolidating several kernel
//!   launches into one graph launch").
//! - **vec_width** — vectorized load width in elements (1/2/4/8).

use crate::util::rng::Pcg;

/// Matmul/conv tiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tile {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
}

impl Tile {
    pub const CHOICES: [Tile; 6] = [
        Tile { bm: 16, bn: 16, bk: 16 },
        Tile { bm: 32, bn: 32, bk: 32 },
        Tile { bm: 64, bn: 64, bk: 32 },
        Tile { bm: 64, bn: 64, bk: 64 },
        Tile { bm: 128, bn: 128, bk: 32 },
        Tile { bm: 128, bn: 128, bk: 64 },
    ];

    /// Bytes of on-chip memory (shared mem / threadgroup mem) one tile
    /// step needs: A-tile + B-tile + C-accumulator at f32.
    pub fn onchip_bytes(&self) -> usize {
        (self.bm * self.bk + self.bk * self.bn + self.bm * self.bn) * 4
    }
}

/// A complete schedule for one candidate program.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// Number of fusion opportunities taken (usize::MAX = all).
    pub fusion_depth: usize,
    pub tile: Tile,
    /// Elements-per-thread for elementwise kernels (§7.2).
    pub ept: usize,
    /// Threads per threadgroup / block.
    pub threadgroup: usize,
    pub fast_math: bool,
    /// Launch amortization: CUDA graphs on CUDA; thread-local cached
    /// pipeline state + command-queue reuse on Metal (§7.2's listing).
    pub use_graphs: bool,
    /// Vector load width (elements).
    pub vec_width: usize,
}

impl Schedule {
    /// The naive schedule: what a first-try, unoptimized program uses.
    pub fn naive() -> Schedule {
        Schedule {
            fusion_depth: 0,
            tile: Tile { bm: 16, bn: 16, bk: 16 },
            ept: 1,
            threadgroup: 256,
            fast_math: false,
            use_graphs: false,
            vec_width: 1,
        }
    }

    /// A strong hand-tuned schedule (what an expert or a top model
    /// converges to) — fully fused, large tiles, 8 elements/thread.
    pub fn expert() -> Schedule {
        Schedule {
            fusion_depth: usize::MAX,
            tile: Tile { bm: 128, bn: 128, bk: 64 },
            ept: 8,
            threadgroup: 256,
            fast_math: true,
            use_graphs: true,
            vec_width: 4,
        }
    }

    /// Platform-appropriate expert point: the on-chip memory budget
    /// caps the tile (`PlatformSpec::expert_tile`), everything else is
    /// the universal expert point.  This is the target the refinement
    /// loop converges to on each platform; `use_graphs` means whatever
    /// launch amortization the platform offers (CUDA/HIP graphs, or
    /// Metal's cached pipeline state — §7.2).
    pub fn expert_for(spec: &crate::platform::PlatformSpec) -> Schedule {
        Schedule {
            tile: spec.expert_tile,
            ..Schedule::expert()
        }
    }

    /// Sample a schedule whose quality follows `skill` ∈ [0,1]: with
    /// probability `skill` each lever takes a strong value, else a
    /// random (often weak) one.  This is how persona skill shapes the
    /// schedule prior (see `agents::generation`).
    pub fn sample(rng: &mut Pcg, skill: f64) -> Schedule {
        let expert = Schedule::expert();
        let mut s = Schedule::naive();
        if rng.chance(skill) {
            s.fusion_depth = expert.fusion_depth;
        } else {
            s.fusion_depth = rng.range_i64(0, 3) as usize;
        }
        s.tile = if rng.chance(skill) {
            expert.tile
        } else {
            *rng.choose(&Tile::CHOICES)
        };
        s.ept = if rng.chance(skill) {
            8
        } else {
            *rng.choose(&[1usize, 1, 2, 4])
        };
        s.threadgroup = *rng.choose(&[64usize, 128, 256, 512, 1024]);
        s.fast_math = rng.chance(skill * 0.8);
        s.use_graphs = rng.chance(skill * 0.2);
        s.vec_width = if rng.chance(skill) { 4 } else { *rng.choose(&[1usize, 2]) };
        s
    }

    /// Move one lever toward the expert point — the action a refinement
    /// iteration takes when the performance recommendation targets that
    /// lever.  Returns true if anything changed.
    pub fn improve(&mut self, lever: Lever) -> bool {
        let expert = Schedule::expert();
        match lever {
            Lever::Fusion => {
                if self.fusion_depth != expert.fusion_depth {
                    self.fusion_depth = expert.fusion_depth;
                    return true;
                }
            }
            Lever::Tile => {
                if self.tile != expert.tile {
                    self.tile = expert.tile;
                    return true;
                }
            }
            Lever::Ept => {
                if self.ept < 8 {
                    self.ept = (self.ept * 2).min(8);
                    return true;
                }
            }
            Lever::Threadgroup => {
                if self.threadgroup != expert.threadgroup {
                    self.threadgroup = expert.threadgroup;
                    return true;
                }
            }
            Lever::FastMath => {
                if !self.fast_math {
                    self.fast_math = true;
                    return true;
                }
            }
            Lever::Graphs => {
                if !self.use_graphs {
                    self.use_graphs = true;
                    return true;
                }
            }
            Lever::VecWidth => {
                if self.vec_width < 4 {
                    self.vec_width = (self.vec_width * 2).min(4);
                    return true;
                }
            }
        }
        false
    }

    /// Canonical single-line rendering of every lever.  This is the
    /// deterministic sort/dedup key the search subsystem uses and the
    /// exact (all-integer, hence lossless) serialization the result
    /// store round-trips tune results through — [`Schedule::from_canon`]
    /// is its strict inverse.
    pub fn canon(&self) -> String {
        format!(
            "fusion={} tile={}x{}x{} ept={} tg={} fast={} graphs={} vec={}",
            if self.fusion_depth == usize::MAX {
                "full".to_string()
            } else {
                self.fusion_depth.to_string()
            },
            self.tile.bm,
            self.tile.bn,
            self.tile.bk,
            self.ept,
            self.threadgroup,
            self.fast_math,
            self.use_graphs,
            self.vec_width
        )
    }

    /// Strict inverse of [`Schedule::canon`]: every field must be
    /// present, well-formed and in order; anything else is an error
    /// (the store treats it as a corrupt entry, i.e. a miss).
    pub fn from_canon(text: &str) -> anyhow::Result<Schedule> {
        use anyhow::Context;
        let mut fields = text.split_whitespace();
        let mut take = |name: &str| -> anyhow::Result<String> {
            let tok = fields
                .next()
                .with_context(|| format!("schedule text truncated before {name}"))?;
            tok.strip_prefix(name)
                .and_then(|rest| rest.strip_prefix('='))
                .map(|v| v.to_string())
                .with_context(|| format!("expected {name}=..., got {tok:?}"))
        };
        let fusion = take("fusion")?;
        let fusion_depth = if fusion == "full" {
            usize::MAX
        } else {
            fusion.parse().with_context(|| format!("bad fusion depth {fusion:?}"))?
        };
        let tile_text = take("tile")?;
        let dims: Vec<&str> = tile_text.split('x').collect();
        anyhow::ensure!(dims.len() == 3, "bad tile {tile_text:?}");
        let tile = Tile {
            bm: dims[0].parse().with_context(|| format!("bad tile {tile_text:?}"))?,
            bn: dims[1].parse().with_context(|| format!("bad tile {tile_text:?}"))?,
            bk: dims[2].parse().with_context(|| format!("bad tile {tile_text:?}"))?,
        };
        let parse_bool = |v: String| -> anyhow::Result<bool> {
            match v.as_str() {
                "true" => Ok(true),
                "false" => Ok(false),
                other => anyhow::bail!("bad bool {other:?}"),
            }
        };
        let ept = take("ept")?.parse().context("bad ept")?;
        let threadgroup = take("tg")?.parse().context("bad threadgroup")?;
        let fast_math = parse_bool(take("fast")?)?;
        let use_graphs = parse_bool(take("graphs")?)?;
        let vec_width = take("vec")?.parse().context("bad vec width")?;
        anyhow::ensure!(fields.next().is_none(), "trailing data after schedule fields");
        Ok(Schedule {
            fusion_depth,
            tile,
            ept,
            threadgroup,
            fast_math,
            use_graphs,
            vec_width,
        })
    }

    /// Distance from the expert schedule in lever count (0 = expert).
    pub fn distance_from_expert(&self) -> usize {
        let e = Schedule::expert();
        let mut d = 0;
        if self.fusion_depth != e.fusion_depth {
            d += 1;
        }
        if self.tile != e.tile {
            d += 1;
        }
        if self.ept != e.ept {
            d += 1;
        }
        if self.fast_math != e.fast_math {
            d += 1;
        }
        if self.use_graphs != e.use_graphs {
            d += 1;
        }
        if self.vec_width != e.vec_width {
            d += 1;
        }
        d
    }
}

/// Schedule levers a performance recommendation can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lever {
    Fusion,
    Tile,
    Ept,
    Threadgroup,
    FastMath,
    Graphs,
    VecWidth,
}

impl Lever {
    pub const ALL: [Lever; 7] = [
        Lever::Fusion,
        Lever::Tile,
        Lever::Ept,
        Lever::Threadgroup,
        Lever::FastMath,
        Lever::Graphs,
        Lever::VecWidth,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Lever::Fusion => "fusion",
            Lever::Tile => "tile",
            Lever::Ept => "elements_per_thread",
            Lever::Threadgroup => "threadgroup_size",
            Lever::FastMath => "fast_math",
            Lever::Graphs => "cuda_graphs",
            Lever::VecWidth => "vectorization",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expert_distance_zero_except_threadgroup() {
        assert_eq!(Schedule::expert().distance_from_expert(), 0);
        assert!(Schedule::naive().distance_from_expert() >= 5);
    }

    #[test]
    fn improve_converges_to_expert() {
        let mut s = Schedule::naive();
        for _ in 0..32 {
            for lever in Lever::ALL {
                s.improve(lever);
            }
        }
        assert_eq!(s.distance_from_expert(), 0);
    }

    #[test]
    fn improve_reports_noop() {
        let mut s = Schedule::expert();
        assert!(!s.improve(Lever::FastMath));
        assert!(!s.improve(Lever::Tile));
    }

    #[test]
    fn high_skill_samples_near_expert() {
        let mut rng = Pcg::seed(0);
        let avg_hi: f64 = (0..200)
            .map(|_| Schedule::sample(&mut rng, 0.95).distance_from_expert() as f64)
            .sum::<f64>()
            / 200.0;
        let avg_lo: f64 = (0..200)
            .map(|_| Schedule::sample(&mut rng, 0.1).distance_from_expert() as f64)
            .sum::<f64>()
            / 200.0;
        assert!(avg_hi < avg_lo, "hi={avg_hi} lo={avg_lo}");
        assert!(avg_hi < 1.5);
        assert!(avg_lo > 3.0);
    }

    #[test]
    fn canon_round_trips_every_sampled_schedule() {
        let mut rng = Pcg::seed(0xCA90);
        for _ in 0..200 {
            let s = Schedule::sample(&mut rng, rng.uniform());
            let back = Schedule::from_canon(&s.canon()).unwrap();
            assert_eq!(back, s, "{}", s.canon());
        }
        // usize::MAX fusion renders as "full" and survives the trip
        let e = Schedule::expert();
        assert!(e.canon().contains("fusion=full"), "{}", e.canon());
        assert_eq!(Schedule::from_canon(&e.canon()).unwrap(), e);
    }

    #[test]
    fn from_canon_rejects_malformed_text() {
        let good = Schedule::naive().canon();
        assert!(Schedule::from_canon("").is_err());
        assert!(Schedule::from_canon(&good.replace("fast=false", "fast=perhaps")).is_err());
        assert!(Schedule::from_canon(&good.replace("tile=16x16x16", "tile=16x16")).is_err());
        assert!(Schedule::from_canon(&format!("{good} extra=1")).is_err());
        // truncated at every field boundary
        for (i, _) in good.match_indices(' ') {
            assert!(Schedule::from_canon(&good[..i]).is_err(), "truncated at {i} parsed");
        }
    }

    #[test]
    fn tile_onchip_bytes() {
        let t = Tile { bm: 64, bn: 64, bk: 32 };
        assert_eq!(t.onchip_bytes(), (64 * 32 + 32 * 64 + 64 * 64) * 4);
    }
}
