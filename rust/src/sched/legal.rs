//! Schedule legality against a platform.
//!
//! A schedule that exceeds device limits fails *at dispatch*, not at
//! compile: this is the paper's **runtime error** execution state
//! (§3.3 — "segmentation faults or program abort").  The generation
//! agent's runtime-class defects (oversized threadgroups, tiles that
//! overflow on-chip memory) are caught here when the plan is "run" on
//! the simulated device.

use super::schedule::Schedule;
use crate::platform::PlatformSpec;
use anyhow::{bail, Result};

/// Check a schedule against device limits.  The error text mimics the
/// driver diagnostics the paper's feedback loop would capture.
pub fn check(s: &Schedule, p: &PlatformSpec) -> Result<()> {
    if s.threadgroup == 0 || s.threadgroup % p.simd_width != 0 {
        bail!(
            "runtime error: invalid threadgroup size {} (must be a non-zero multiple of {})",
            s.threadgroup,
            p.simd_width
        );
    }
    if s.threadgroup > p.max_threadgroup {
        bail!(
            "runtime error: threadgroup size {} exceeds device maximum {} \
             (maxTotalThreadsPerThreadgroup)",
            s.threadgroup,
            p.max_threadgroup
        );
    }
    if s.tile.onchip_bytes() > p.onchip_bytes {
        bail!(
            "runtime error: tile ({},{},{}) requires {} bytes of on-chip memory, device has {}",
            s.tile.bm,
            s.tile.bn,
            s.tile.bk,
            s.tile.onchip_bytes(),
            p.onchip_bytes
        );
    }
    if !s.ept.is_power_of_two() || s.ept > 16 {
        bail!("runtime error: elements-per-thread {} unsupported (1..16, pow2)", s.ept);
    }
    if !s.vec_width.is_power_of_two() || s.vec_width > 8 {
        bail!("runtime error: vector width {} unsupported", s.vec_width);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{cuda, metal};
    use crate::sched::schedule::Tile;

    #[test]
    fn naive_and_expert_legal_on_cuda() {
        let p = cuda::h100();
        assert!(check(&Schedule::naive(), &p).is_ok());
        assert!(check(&Schedule::expert(), &p).is_ok());
    }

    #[test]
    fn expert_tile_overflows_metal_onchip() {
        // 128x128x64 tile needs ~96KB; M4 Max has 32KB threadgroup mem.
        let p = metal::m4_max();
        let mut s = Schedule::expert();
        s.use_graphs = false;
        let err = check(&s, &p).unwrap_err().to_string();
        assert!(err.contains("on-chip"), "{err}");
    }

    #[test]
    fn launch_amortization_legal_on_metal() {
        // on Metal `use_graphs` means cached pipeline state (§7.2's
        // thread-local caching), which is always legal
        let p = metal::m4_max();
        let mut s = Schedule::naive();
        s.use_graphs = true;
        assert!(check(&s, &p).is_ok());
    }

    #[test]
    fn oversized_threadgroup_rejected() {
        let p = cuda::h100();
        let mut s = Schedule::naive();
        s.threadgroup = 2048;
        let err = check(&s, &p).unwrap_err().to_string();
        assert!(err.contains("exceeds device maximum"), "{err}");
    }

    #[test]
    fn non_warp_multiple_rejected() {
        let p = cuda::h100();
        let mut s = Schedule::naive();
        s.threadgroup = 100;
        assert!(check(&s, &p).is_err());
    }

    #[test]
    fn huge_tile_rejected() {
        let p = cuda::h100();
        let mut s = Schedule::naive();
        s.tile = Tile { bm: 512, bn: 512, bk: 64 };
        assert!(check(&s, &p).is_err());
    }
}
