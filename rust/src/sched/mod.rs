//! The schedule space: how a synthesized program maps its graph onto a
//! platform.  This is the paper's CUDA/Metal optimization vocabulary
//! (threadblock tiling, elements-per-thread, fast-math intrinsics,
//! CUDA graphs) as an explicit searchable space.

pub mod schedule;
pub mod legal;

pub use schedule::{Schedule, Tile};
