//! torch.compile (TorchInductor, default mode) analog.
//!
//! Greedy epilogue fusion + generic (not workload-tuned) schedules and
//! memory planning.  Reproduces the baseline behaviors the paper
//! reports:
//! - L1/L2: often *slower* than eager — per-op compiled kernels lose
//!   to tuned vendor kernels on single primitives, and guard/dispatch
//!   overhead is paid on every call (§5.2, Fig 3 caption);
//! - L3: graph-level optimization wins once there are many ops (§4.1);
//! - large batch: planning wins; small batch: overhead loses (Table 6).

use crate::kir::rewrite::fusion;
use crate::kir::Graph;
use crate::perfsim::lower::lower_with_plan;
use crate::perfsim::{simulate, Plan, SimResult};
use crate::platform::PlatformSpec;
use crate::sched::Schedule;
use crate::util::rng::Pcg;

/// Inductor-style generated-kernel schedule: fused, vectorized, but
/// generic tiles (codegen does not hit cuBLAS-level tiles on every
/// shape, `PlatformSpec::inductor_tile`) and no fast-math by default.
pub fn inductor_schedule(spec: &PlatformSpec) -> Schedule {
    Schedule {
        fusion_depth: usize::MAX,
        tile: spec.inductor_tile,
        ept: 4,
        threadgroup: 256,
        fast_math: false,
        // torch.compile *default* mode does not capture CUDA graphs
        // (that is mode="reduce-overhead"); the paper benchmarks the
        // default TorchInductor backend (§4.1)
        use_graphs: false,
        vec_width: 4,
    }
}

/// Per-call guard/dispatch overhead torch.compile pays at the Python
/// boundary (shape guards, cache lookup) — significant on tiny graphs.
pub const GUARD_OVERHEAD_S: f64 = 12.0e-6;

/// Lower a graph the inductor way.
pub fn plan(g: &Graph, spec: &PlatformSpec) -> Plan {
    let s = inductor_schedule(spec);
    let fplan = fusion::greedy_epilogue(g);
    lower_with_plan(g, &s, &fplan)
}

/// Measure torch.compile execution: simulated plan + guard overhead.
pub fn measure(g: &Graph, spec: &PlatformSpec, rng: &mut Pcg) -> SimResult {
    let mut sim = simulate(spec, &plan(g, spec), rng, super::RUNS, super::WARMUP);
    sim.ideal_s += GUARD_OVERHEAD_S;
    sim.measured_s += GUARD_OVERHEAD_S * rng.lognormal_noise(0.05);
    sim
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::eager;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::platform::cuda;
    use crate::tensor::Shape;

    /// Small single-op problem: compile's guard overhead makes it lose.
    #[test]
    fn compile_loses_on_tiny_level1() {
        let mut b = GraphBuilder::new("tiny");
        let x = b.input(Shape::of(&[256]));
        let r = b.unary(UnaryKind::Swish, x);
        let g = b.finish(vec![r]);
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        let e = eager::measure(&g, &spec, &mut rng);
        let c = measure(&g, &spec, &mut rng);
        assert!(c.measured_s > e.measured_s, "compile {} eager {}", c.measured_s, e.measured_s);
    }

    /// Deep multi-op graph: fusion + graphs beat eager's launch storm.
    #[test]
    fn compile_wins_on_deep_level3_like_graph() {
        let mut b = GraphBuilder::new("deep");
        let mut x = b.input(Shape::of(&[64, 64]));
        let w = b.input(Shape::of(&[64, 64]));
        for _ in 0..12 {
            let m = b.matmul(x, w);
            x = b.unary(UnaryKind::Relu, m);
        }
        let g = b.finish(vec![x]);
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        let e = eager::measure(&g, &spec, &mut rng);
        let c = measure(&g, &spec, &mut rng);
        assert!(c.measured_s < e.measured_s, "compile {} eager {}", c.measured_s, e.measured_s);
    }

    #[test]
    fn plan_fuses() {
        let mut b = GraphBuilder::new("f");
        let x = b.input(Shape::of(&[64, 64]));
        let w = b.input(Shape::of(&[64, 64]));
        let m = b.matmul(x, w);
        let r = b.unary(UnaryKind::Relu, m);
        let g = b.finish(vec![r]);
        let spec = cuda::h100();
        assert_eq!(plan(&g, &spec).launches(), 1);
    }
}
