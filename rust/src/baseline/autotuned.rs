//! Autotuned-search baseline: the strongest non-agent comparator.
//!
//! Where [`super::eager`] runs stock per-op kernels and
//! [`super::compilebase`] a generic compiled schedule, this arm runs
//! the schedule the [`crate::search`] beam autotuner finds for the
//! workload — turning "agent vs. naive/expert" comparisons into
//! "agent vs. best-effort search" (`--baseline autotuned` on campaigns,
//! the "Autotuned Search" rows of Table 6).
//!
//! The search is deterministic in (platform spec, graph) alone and is
//! memoized process-wide: a campaign prices the same perf graph once
//! per persona per measurement, but searches it exactly once.

use crate::kir::Graph;
use crate::perfsim::lower::lower;
use crate::perfsim::{simulate, SimResult};
use crate::platform::PlatformSpec;
use crate::sched::Schedule;
use crate::search::{BeamStrategy, Budget, CostOracle, SearchStrategy};
use crate::store::key::{graph_fingerprint, spec_hash};
use crate::util::rng::Pcg;
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Search budget the baseline arm gives each distinct (spec, graph) —
/// enough beam rounds to stack several lever moves without turning a
/// campaign baseline into a tuning campaign.  Changing this changes
/// baseline semantics: bump `store::STORE_SCHEMA` in the same PR.
pub const BASELINE_BUDGET: usize = 128;
/// Early-stop patience for the baseline search.
pub const BASELINE_PATIENCE: usize = 2;

/// Find (and memoize) the best-found schedule for a graph on a spec.
/// Never worse than naive — the naive seed plus an explicit fallback
/// guarantee it.  No evidence re-rank here: the baseline arm must be a
/// pure function of (spec, graph), independent of which profiler
/// frontend a platform registers.
pub fn schedule_for(g: &Graph, spec: &PlatformSpec) -> Schedule {
    static MEMO: OnceLock<Mutex<HashMap<(u64, u64), Schedule>>> = OnceLock::new();
    let key = (spec_hash(spec), graph_fingerprint(g));
    let memo = MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(s) = memo.lock().unwrap().get(&key) {
        return s.clone();
    }
    // single-worker oracle: campaign baselines run on worker threads
    // that are already the parallelism
    let oracle = CostOracle::new(spec, g);
    let naive_cost = oracle.cost(&Schedule::naive());
    let mut budget = Budget::new(BASELINE_BUDGET, BASELINE_PATIENCE);
    let mut rng = Pcg::new(0xA070_7E5E, key.0 ^ key.1);
    let out = BeamStrategy::default().search(&oracle, &mut budget, &mut rng);
    let best = if out.best.cost_s <= naive_cost {
        out.best.schedule
    } else {
        Schedule::naive()
    };
    memo.lock().unwrap().insert(key, best.clone());
    best
}

/// Measure the autotuned baseline with the paper's protocol (100 runs
/// / 10 warmup, seeded noise) — the drop-in sibling of
/// [`super::eager::measure`] / [`super::compilebase::measure`].
pub fn measure(g: &Graph, spec: &PlatformSpec, rng: &mut Pcg) -> SimResult {
    let s = schedule_for(g, spec);
    simulate(spec, &lower(g, &s), rng, super::RUNS, super::WARMUP)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::eager;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::platform::{cuda, registry};
    use crate::tensor::Shape;

    fn g() -> Graph {
        let mut b = GraphBuilder::new("auto");
        let x = b.input(Shape::of(&[64, 64]));
        let w = b.input(Shape::of(&[64, 64]));
        let m = b.matmul(x, w);
        let r = b.unary(UnaryKind::Swish, m);
        b.finish(vec![r])
    }

    #[test]
    fn autotuned_never_loses_to_eager_with_aligned_noise() {
        // measured with the same rng stream, autotuned <= eager exactly:
        // the stock schedule seeds the search, so the tuned ideal time
        // is <= the eager plan's, and the noise multipliers cancel
        let graph = g();
        for platform in registry().platforms() {
            let spec = platform.spec();
            let mut r1 = Pcg::seed(42);
            let mut r2 = Pcg::seed(42);
            let e = eager::measure(&graph, spec, &mut r1);
            let a = measure(&graph, spec, &mut r2);
            assert!(
                a.measured_s <= e.measured_s,
                "{}: autotuned {} > eager {}",
                platform.name(),
                a.measured_s,
                e.measured_s
            );
        }
    }

    #[test]
    fn schedule_is_memoized_legal_and_deterministic() {
        let spec = cuda::h100();
        let graph = g();
        let a = schedule_for(&graph, &spec);
        let b = schedule_for(&graph, &spec);
        assert_eq!(a, b);
        crate::sched::legal::check(&a, &spec).unwrap();
        // a different spec searches a different space
        let m = crate::platform::metal::m4_max();
        let c = schedule_for(&graph, &m);
        crate::sched::legal::check(&c, &m).unwrap();
    }
}
