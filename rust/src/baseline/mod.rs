//! Baseline executors — the comparators of every paper table/figure.
//!
//! - [`eager`] — PyTorch-eager analog: one kernel per op, stock
//!   schedule, no fusion (the §5.1 / §6.1 baseline).
//! - [`compilebase`] — torch.compile (TorchInductor, default mode)
//!   analog: greedy epilogue fusion + sane-but-generic schedules, plus
//!   the compile-context behavior the paper controls for (§4.1).

pub mod eager;
pub mod compilebase;

/// The paper's measurement protocol constants (§4.1): execution time
/// across 100 runs with 10 warmup steps.
pub const RUNS: usize = 100;
pub const WARMUP: usize = 10;
