//! Baseline executors — the comparators of every paper table/figure.
//!
//! - [`eager`] — PyTorch-eager analog: one kernel per op, stock
//!   schedule, no fusion (the §5.1 / §6.1 baseline).
//! - [`compilebase`] — torch.compile (TorchInductor, default mode)
//!   analog: greedy epilogue fusion + sane-but-generic schedules, plus
//!   the compile-context behavior the paper controls for (§4.1).
//! - [`autotuned`] — the schedule the [`crate::search`] beam autotuner
//!   finds for the workload: the best-effort *non-agent* comparator
//!   (`--baseline autotuned`, Table 6's "Autotuned Search" rows).

pub mod eager;
pub mod compilebase;
pub mod autotuned;

/// The paper's measurement protocol constants (§4.1): execution time
/// across 100 runs with 10 warmup steps.
pub const RUNS: usize = 100;
pub const WARMUP: usize = 10;
