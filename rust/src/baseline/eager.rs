//! PyTorch-eager-mode analog: one kernel per op, stock schedules.
//!
//! Eager mode is the reference implementation every speedup in the
//! paper is computed against (Fig 2, Fig 4, Table 6 "PyTorch Eager").
//! Stock kernels are *good* kernels — vendor libraries tile matmuls
//! well — so the schedule is competent on compute, but nothing is
//! fused and every op pays a launch.

use crate::kir::rewrite::fusion;
use crate::kir::Graph;
use crate::perfsim::lower::lower_with_plan;
use crate::perfsim::{simulate, Plan, SimResult};
use crate::platform::PlatformSpec;
use crate::sched::Schedule;
use crate::util::rng::Pcg;

/// The schedule stock vendor kernels effectively run with: decent
/// tiles and vectorization (cuBLAS/MPS/rocBLAS are well tuned per
/// kernel, `PlatformSpec::stock_tile`), no fusion, no graphs, no
/// fast-math.
pub fn stock_schedule(spec: &PlatformSpec) -> Schedule {
    Schedule {
        fusion_depth: 0,
        tile: spec.stock_tile,
        ept: 4,
        threadgroup: 256,
        fast_math: false,
        use_graphs: false,
        vec_width: 4,
    }
}

/// Lower a graph the eager way.
pub fn plan(g: &Graph, spec: &PlatformSpec) -> Plan {
    let s = stock_schedule(spec);
    let fplan = fusion::none(g);
    lower_with_plan(g, &s, &fplan)
}

/// Measure eager execution (the paper's protocol).
pub fn measure(g: &Graph, spec: &PlatformSpec, rng: &mut Pcg) -> SimResult {
    simulate(spec, &plan(g, spec), rng, super::RUNS, super::WARMUP)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::platform::cuda;
    use crate::tensor::Shape;

    fn g() -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input(Shape::of(&[64, 64]));
        let w = b.input(Shape::of(&[64, 64]));
        let m = b.matmul(x, w);
        let r = b.unary(UnaryKind::Relu, m);
        b.finish(vec![r])
    }

    #[test]
    fn eager_launches_equal_op_count() {
        let spec = cuda::h100();
        let p = plan(&g(), &spec);
        assert_eq!(p.launches(), 2);
    }

    #[test]
    fn eager_measure_positive() {
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        let r = measure(&g(), &spec, &mut rng);
        assert!(r.measured_s > 0.0);
    }
}
