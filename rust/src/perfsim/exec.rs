//! Plan execution on the simulated device: assembles the per-kernel
//! costs into a timeline (the profiler renders it), applies seeded
//! measurement noise, and implements the paper's 100-run/10-warmup
//! measurement protocol.

use super::cost::{kernel_cost, launch_cost, KernelCost};
use super::lower::Plan;
use crate::platform::PlatformSpec;
use crate::sched::Schedule;
use crate::util::rng::Pcg;
use crate::util::stats;

/// Host-side floor per forward call (framework dispatch, buffer
/// lookups): even a constant-returning model pays this (~the paper's
/// "approx 30 us ... bare Python dispatch overhead" on MPS, scaled to
/// the lean rust path).
pub const HOST_OVERHEAD_S: f64 = 2.0e-6;

/// One simulated kernel execution interval on the device timeline.
#[derive(Debug, Clone)]
pub struct TimelineEntry {
    pub name: String,
    pub start_s: f64,
    pub duration_s: f64,
    pub cost: KernelCost,
    /// Idle gap before this kernel (dispatch latency) — the "scheduling
    /// gaps" a timeline view surfaces (§3, profiling information).
    pub gap_before_s: f64,
}

/// Result of simulating one plan execution.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub timeline: Vec<TimelineEntry>,
    /// Noise-free model time for one run (seconds).
    pub ideal_s: f64,
    /// Measured mean over the protocol (noise applied), seconds.
    pub measured_s: f64,
    pub total_flops: f64,
    pub total_bytes: f64,
}

impl SimResult {
    /// Device busy fraction (1 - gaps).
    pub fn busy_fraction(&self) -> f64 {
        let busy: f64 = self.timeline.iter().map(|t| t.duration_s).sum();
        busy / self.ideal_s.max(1e-15)
    }
}

/// Build the device timeline for one plan execution and return it with
/// the noise-free model time.  This is the single pricing path: both
/// [`simulate`] and the schedule autotuner's [`ideal_time`] go through
/// it, so a schedule search can never rank by a cost model that drifts
/// from what the measurement protocol then reports.
fn build_timeline(spec: &PlatformSpec, plan: &Plan) -> (Vec<TimelineEntry>, f64) {
    let s = &plan.schedule;
    let n = plan.kernels.len();
    let total_launch = launch_cost(spec, s, n);
    let per_launch = if n > 0 { total_launch / n as f64 } else { 0.0 };
    let mut timeline = Vec::with_capacity(n);
    let mut clock = 0.0;
    let mut prev_body = 0.0f64;
    for (i, k) in plan.kernels.iter().enumerate() {
        let cost = kernel_cost(spec, s, k);
        // Launch-latency hiding: the host enqueues asynchronously, so
        // the device only idles when the previous kernel finishes before
        // the next launch lands (the paper's T_o ≫ T_c small-kernel
        // regime).  A small per-dispatch floor always remains.
        let gap = if i == 0 {
            per_launch
        } else {
            (per_launch - prev_body).max(per_launch * 0.12)
        };
        clock += gap;
        timeline.push(TimelineEntry {
            name: k.name.clone(),
            start_s: clock,
            duration_s: cost.total_s,
            cost,
            gap_before_s: gap,
        });
        clock += cost.total_s;
        prev_body = cost.total_s;
    }
    (timeline, clock + HOST_OVERHEAD_S)
}

/// Noise-free model time for one run of `plan` — bit-identical to the
/// `ideal_s` a [`simulate`] call would report, with no RNG involved.
/// The schedule autotuner ranks candidates by this, which is what makes
/// seeded search results independent of worker count and measurement
/// noise alike.
pub fn ideal_time(spec: &PlatformSpec, plan: &Plan) -> f64 {
    build_timeline(spec, plan).1
}

/// Noise-free model time from per-kernel body durations alone.  This
/// is [`build_timeline`]'s fold with the kernel costing factored out:
/// `ideal_from_bodies(spec, s, bodies)` where `bodies[i]` is kernel i's
/// `kernel_cost(..).total_s` returns exactly [`ideal_time`]'s result,
/// bit for bit (same statements, same order — float addition is not
/// associative, so the fold is kept textually identical).  The oracle's
/// dirty-region re-pricing recomputes only changed bodies and re-runs
/// this cheap fold over the full sequence.
pub fn ideal_from_bodies(spec: &PlatformSpec, s: &Schedule, bodies: &[f64]) -> f64 {
    let n = bodies.len();
    let total_launch = launch_cost(spec, s, n);
    let per_launch = if n > 0 { total_launch / n as f64 } else { 0.0 };
    let mut clock = 0.0;
    let mut prev_body = 0.0f64;
    for (i, &b) in bodies.iter().enumerate() {
        let gap = if i == 0 {
            per_launch
        } else {
            (per_launch - prev_body).max(per_launch * 0.12)
        };
        clock += gap;
        clock += b;
        prev_body = b;
    }
    clock + HOST_OVERHEAD_S
}

/// Simulate a plan: build the timeline, price launches, apply the
/// measurement protocol (`runs` timed runs after `warmup`, lognormal
/// noise from the platform's sigma, seeded).
pub fn simulate(spec: &PlatformSpec, plan: &Plan, rng: &mut Pcg, runs: usize, warmup: usize) -> SimResult {
    let (timeline, ideal) = build_timeline(spec, plan);
    // measurement protocol: warmup runs discarded, mean of the rest
    let mut samples = Vec::with_capacity(runs + warmup);
    for i in 0..(runs + warmup) {
        // first runs include compilation/caching warm-up inflation
        let cold = if i == 0 { 3.0 } else if i < warmup { 1.2 } else { 1.0 };
        samples.push(ideal * cold * rng.lognormal_noise(spec.noise_sigma));
    }
    let measured = stats::timed_mean(&samples, warmup);
    SimResult {
        timeline,
        ideal_s: ideal,
        measured_s: measured,
        total_flops: plan.total_flops(),
        total_bytes: plan.total_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::perfsim::lower::lower;
    use crate::platform::cuda;
    use crate::sched::Schedule;
    use crate::tensor::Shape;

    fn plan(fused: bool, dim: usize) -> Plan {
        let mut b = GraphBuilder::new("t");
        let x = b.input(Shape::of(&[dim, dim]));
        let w = b.input(Shape::of(&[dim, dim]));
        let bias = b.input(Shape::of(&[dim]));
        let m = b.matmul(x, w);
        let a = b.add(m, bias);
        let r = b.unary(UnaryKind::Relu, a);
        let g = b.finish(vec![r]);
        let mut s = Schedule::naive();
        if fused {
            s.fusion_depth = usize::MAX;
            s.tile = crate::sched::schedule::Tile { bm: 128, bn: 128, bk: 64 };
        }
        lower(&g, &s)
    }

    #[test]
    fn fused_beats_eager() {
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        let e = simulate(&spec, &plan(false, 64), &mut rng, 100, 10);
        let f = simulate(&spec, &plan(true, 64), &mut rng, 100, 10);
        assert!(f.ideal_s < e.ideal_s, "fused {} eager {}", f.ideal_s, e.ideal_s);
    }

    #[test]
    fn small_batch_launch_dominated() {
        // at dim=32, launch overhead >> compute: eager pays 3 launches
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        let e = simulate(&spec, &plan(false, 32), &mut rng, 100, 10);
        let body: f64 = e.timeline.iter().map(|t| t.duration_s).sum();
        let gaps: f64 = e.timeline.iter().map(|t| t.gap_before_s).sum();
        assert!(gaps > body, "gaps {gaps} body {body}");
    }

    #[test]
    fn measurement_noise_is_bounded_and_deterministic() {
        let spec = cuda::h100();
        let p = plan(true, 64);
        let mut r1 = Pcg::seed(7);
        let mut r2 = Pcg::seed(7);
        let a = simulate(&spec, &p, &mut r1, 100, 10);
        let b = simulate(&spec, &p, &mut r2, 100, 10);
        assert_eq!(a.measured_s, b.measured_s);
        assert!((a.measured_s / a.ideal_s - 1.0).abs() < 0.2);
    }

    #[test]
    fn ideal_time_matches_simulate_bitwise() {
        let spec = cuda::h100();
        for (fused, dim) in [(false, 32), (false, 64), (true, 64)] {
            let p = plan(fused, dim);
            let mut rng = Pcg::seed(3);
            let sim = simulate(&spec, &p, &mut rng, 10, 2);
            assert_eq!(
                ideal_time(&spec, &p).to_bits(),
                sim.ideal_s.to_bits(),
                "fused={fused} dim={dim}"
            );
        }
    }

    #[test]
    fn ideal_from_bodies_matches_ideal_time_bitwise() {
        let spec = cuda::h100();
        for (fused, dim) in [(false, 32), (false, 64), (true, 64), (true, 128)] {
            let p = plan(fused, dim);
            let bodies: Vec<f64> = p
                .kernels
                .iter()
                .map(|k| kernel_cost(&spec, &p.schedule, k).total_s)
                .collect();
            assert_eq!(
                ideal_from_bodies(&spec, &p.schedule, &bodies).to_bits(),
                ideal_time(&spec, &p).to_bits(),
                "fused={fused} dim={dim}"
            );
        }
    }

    #[test]
    fn timeline_monotonic() {
        let spec = cuda::h100();
        let mut rng = Pcg::seed(0);
        let r = simulate(&spec, &plan(false, 64), &mut rng, 10, 2);
        for w in r.timeline.windows(2) {
            assert!(w[1].start_s >= w[0].start_s + w[0].duration_s - 1e-15);
        }
    }
}
