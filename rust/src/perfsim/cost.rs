//! Per-kernel cost model: roofline + schedule-dependent utilization.
//!
//! `t_kernel = max(t_compute, t_memory) + t_launch` where utilizations
//! are functions of the schedule — this is where tile sizes, vector
//! width, elements-per-thread, occupancy and fast-math earn their keep.

use super::lower::{KernelClass, KernelLaunch};
use crate::platform::PlatformSpec;
use crate::sched::Schedule;

/// Breakdown of one kernel's simulated time.
#[derive(Debug, Clone, Copy)]
pub struct KernelCost {
    pub compute_s: f64,
    pub memory_s: f64,
    pub launch_s: f64,
    /// max(compute, memory) + launch
    pub total_s: f64,
    /// Utilization diagnostics surfaced to the profiler.
    pub mm_utilization: f64,
    pub mem_utilization: f64,
    pub occupancy: f64,
}

/// Matmul-engine utilization as a function of tile size: small tiles
/// starve the MM pipe (low data reuse), oversized tiles lose occupancy.
/// Peaks near the platform's sweet spot (`PlatformSpec::tile_sweet_spot`:
/// 128 on H100 and MI300X, 64 on M-series).
fn tile_utilization(spec: &PlatformSpec, s: &Schedule) -> f64 {
    let sweet = spec.tile_sweet_spot;
    let t = s.tile.bm.min(s.tile.bn) as f64;
    // reuse grows ~ t/sweet up to 1; bk adds pipeline efficiency
    let reuse = (t / sweet).min(1.0);
    let depth = (s.tile.bk as f64 / 64.0).min(1.0) * 0.2 + 0.8;
    (0.15 + 0.85 * reuse) * depth
}

/// Effective memory efficiency: vectorized/coalesced access and
/// elements-per-thread amortize per-access overhead (§7.2's "better
/// memory throughput" from 8 elements/thread).
fn memory_efficiency(s: &Schedule) -> f64 {
    let vec = match s.vec_width {
        1 => 0.55,
        2 => 0.75,
        4 => 0.95,
        _ => 0.9, // 8-wide: slightly over-wide, register pressure
    };
    let ept = match s.ept {
        1 => 0.8,
        2 => 0.88,
        4 => 0.95,
        8 => 1.0,
        16 => 0.85,  // over-looping: register spills begin
        32 => 0.70,  // fixed-grid kernels run far off their sweet spot
        64 => 0.55,  // (the Table-6 large-batch degradation mechanism)
        _ => 0.45,
    };
    vec * ept
}

/// Occupancy from threadgroup size vs device geometry: too small wastes
/// scheduler slots, too large limits resident groups.
fn occupancy(spec: &PlatformSpec, s: &Schedule, out_elems: usize) -> f64 {
    let tg = s.threadgroup as f64;
    let shape_factor = if tg <= 64.0 {
        0.7
    } else if tg <= 512.0 {
        1.0
    } else {
        0.85
    };
    // tail effect: fewer threadgroups than cores leaves the device idle
    let work_per_thread = s.ept.max(1);
    let groups = (out_elems as f64 / (tg * work_per_thread as f64)).ceil();
    let tail = (groups / spec.num_cores as f64).min(1.0).max(0.05);
    shape_factor * (0.3 + 0.7 * tail)
}

/// Transcendental slowdown factor: exp/tanh cost extra vector cycles
/// unless fast-math intrinsics are on (§7.2's fast::exp).
fn transcendental_penalty(k: &KernelLaunch, s: &Schedule) -> f64 {
    if k.transcendental_elems <= 0.0 {
        return 1.0;
    }
    let frac = (k.transcendental_elems / k.out_elems.max(1) as f64).min(4.0);
    if s.fast_math {
        1.0 + 0.05 * frac
    } else {
        1.0 + 0.35 * frac
    }
}

/// Price one kernel.
pub fn kernel_cost(spec: &PlatformSpec, s: &Schedule, k: &KernelLaunch) -> KernelCost {
    let occ = occupancy(spec, s, k.out_elems);
    let (peak, mm_util) = match k.class {
        KernelClass::MatmulLike | KernelClass::Attention => {
            let u = tile_utilization(spec, s) * occ;
            (spec.peak_flops_mm, u)
        }
        _ => (spec.peak_flops_f32, occ),
    };
    let mem_eff = memory_efficiency(s) * (0.5 + 0.5 * occ);
    let t_pen = transcendental_penalty(k, s);
    let compute_s = k.flops / (peak * mm_util.max(1e-3)) * t_pen;
    let memory_s = k.bytes_total() / (spec.mem_bw * mem_eff.max(1e-3));
    // reductions serialize a dependency chain: mild latency adder
    let chain = if k.class == KernelClass::Reduction {
        1.15
    } else {
        1.0
    };
    let body = compute_s.max(memory_s) * chain;
    KernelCost {
        compute_s,
        memory_s,
        launch_s: 0.0, // accounted at plan level (graphs amortization)
        total_s: body,
        mm_utilization: mm_util,
        mem_utilization: mem_eff,
        occupancy: occ,
    }
}

/// Launch cost for a whole plan: with the launch-consolidation lever
/// on, the per-dispatch overhead amortizes the way the platform's
/// mechanism dictates (`PlatformSpec::launch_amortization`).
pub fn launch_cost(spec: &PlatformSpec, s: &Schedule, n_kernels: usize) -> f64 {
    use crate::platform::LaunchAmortization;
    if n_kernels == 0 {
        return 0.0;
    }
    if !s.use_graphs {
        return n_kernels as f64 * (spec.launch_overhead + spec.dispatch_overhead);
    }
    match spec.launch_amortization {
        // one graph launch + tiny per-node replay cost (CUDA/HIP graphs)
        LaunchAmortization::DeviceGraphs { replay_per_node_s } => {
            spec.launch_overhead + n_kernels as f64 * replay_per_node_s
        }
        // cached pipeline state / command-queue reuse (§7.2): the
        // encoder setup cost drops away, dispatch remains
        LaunchAmortization::PipelineCache { dispatch_factor } => {
            n_kernels as f64 * (dispatch_factor * spec.launch_overhead)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{cuda, metal};
    use crate::sched::schedule::Tile;

    fn mm_kernel(flops: f64, bytes: f64) -> KernelLaunch {
        KernelLaunch {
            nodes: vec![0],
            name: "matmul".into(),
            class: KernelClass::MatmulLike,
            flops,
            transcendental_elems: 0.0,
            bytes_read: bytes * 0.66,
            bytes_written: bytes * 0.34,
            out_elems: 1 << 20,
        }
    }

    #[test]
    fn bigger_tiles_faster_matmul() {
        let spec = cuda::h100();
        let k = mm_kernel(1e12, 1e8);
        let mut small = Schedule::naive();
        small.tile = Tile { bm: 16, bn: 16, bk: 16 };
        let mut big = small.clone();
        big.tile = Tile { bm: 128, bn: 128, bk: 64 };
        assert!(
            kernel_cost(&spec, &big, &k).total_s < kernel_cost(&spec, &small, &k).total_s
        );
    }

    #[test]
    fn fast_math_helps_transcendental_kernels() {
        let spec = metal::m4_max();
        let mut k = mm_kernel(1e9, 1e9);
        k.class = KernelClass::Elementwise;
        k.transcendental_elems = k.out_elems as f64;
        let mut s = Schedule::naive();
        // make the kernel compute-bound so the penalty is visible
        k.flops = 1e12;
        let slow = kernel_cost(&spec, &s, &k).total_s;
        s.fast_math = true;
        let fast = kernel_cost(&spec, &s, &k).total_s;
        assert!(fast < slow, "fast={fast} slow={slow}");
    }

    #[test]
    fn vectorization_helps_memory_bound() {
        let spec = cuda::h100();
        let mut k = mm_kernel(1e6, 1e10);
        k.class = KernelClass::Elementwise;
        let mut s = Schedule::naive();
        s.vec_width = 1;
        let narrow = kernel_cost(&spec, &s, &k).total_s;
        s.vec_width = 4;
        s.ept = 8;
        let wide = kernel_cost(&spec, &s, &k).total_s;
        assert!(wide < narrow);
    }

    #[test]
    fn graphs_amortize_launches() {
        let spec = cuda::h100();
        let mut s = Schedule::naive();
        let plain = launch_cost(&spec, &s, 50);
        s.use_graphs = true;
        let graphed = launch_cost(&spec, &s, 50);
        assert!(graphed < plain / 5.0, "graphed={graphed} plain={plain}");
    }

    #[test]
    fn tiny_workload_occupancy_low() {
        let spec = cuda::h100();
        let s = Schedule::naive();
        let tiny = occupancy(&spec, &s, 256);
        let big = occupancy(&spec, &s, 1 << 22);
        assert!(tiny < big);
    }

    #[test]
    fn cost_is_at_least_roofline() {
        let spec = cuda::h100();
        let s = Schedule::expert();
        let k = mm_kernel(1e12, 1e8);
        let c = kernel_cost(&spec, &s, &k);
        let ideal = spec.roofline_seconds(k.flops, k.bytes_total(), true);
        assert!(c.total_s >= ideal * 0.99, "cost {} < roofline {}", c.total_s, ideal);
    }
}
