//! The device simulator: lowers (graph, schedule) to a kernel-launch
//! plan and prices it on a platform with a roofline + launch-overhead
//! + occupancy model.
//!
//! What the model must (and does) capture for the paper's results to
//! reproduce:
//! - fusion removes kernel launches *and* intermediate HBM traffic —
//!   the dominant optimization (§5.1, §7.2);
//! - at small batch, `T_o >> T_m, T_c`: launch overhead dominates and
//!   launch-count reductions win (Table 6 small-batch regime; §5.1's
//!   measurement discussion);
//! - tile choice sets matmul-engine utilization (MXU/tensor-core
//!   efficiency), elements-per-thread and vector width set effective
//!   memory bandwidth (§7.2);
//! - CUDA graphs amortize per-dispatch overhead (§5.1);
//! - fast-math accelerates transcendental-heavy kernels (§7.2).

pub mod lower;
pub mod cost;
pub mod exec;

pub use exec::{ideal_time, simulate, SimResult};
pub use lower::{KernelClass, KernelLaunch, Plan};
