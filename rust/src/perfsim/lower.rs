//! Lowering: (graph, schedule) → kernel-launch plan.
//!
//! Fusion groups become kernels.  Each kernel accounts its FLOPs and
//! its *external* memory traffic: group inputs are read once, group
//! outputs written once, interior values stay on-chip — this is exactly
//! why fusion wins, and the accounting makes that fall out naturally.

use crate::kir::graph::{node_flops, Graph, NodeId};
use crate::kir::op::Op;
use crate::kir::rewrite::fusion::{self, FusionPlan};
use crate::sched::Schedule;
use std::collections::HashSet;

/// Kernel cost class — which execution pipe dominates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelClass {
    /// Matmul/conv family — runs on the MM engine (tensor core / MXU).
    MatmulLike,
    /// Elementwise/broadcast — memory-bound streaming.
    Elementwise,
    /// Row reductions / softmax / norm — memory-bound with a reduction
    /// dependency chain.
    Reduction,
    /// Attention — MM engine + on-chip softmax.
    Attention,
    /// Data movement (concat, transpose, pooling).
    Movement,
}

/// One kernel launch in the lowered plan.
#[derive(Debug, Clone)]
pub struct KernelLaunch {
    /// Topologically-ordered node ids fused into this kernel.
    pub nodes: Vec<NodeId>,
    /// Human-readable name, e.g. `matmul+add+relu`.
    pub name: String,
    pub class: KernelClass,
    pub flops: f64,
    /// Transcendental-op element count (fast-math lever applies here).
    pub transcendental_elems: f64,
    /// Bytes read from HBM (external inputs of the group).
    pub bytes_read: f64,
    /// Bytes written to HBM (group outputs).
    pub bytes_written: f64,
    /// Output elements (threadgroup sizing / occupancy input).
    pub out_elems: usize,
}

impl KernelLaunch {
    pub fn bytes_total(&self) -> f64 {
        self.bytes_read + self.bytes_written
    }

    /// Arithmetic intensity (flop/byte).
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes_total().max(1.0)
    }
}

/// A lowered plan: the kernel sequence one forward pass executes.
#[derive(Debug, Clone)]
pub struct Plan {
    pub kernels: Vec<KernelLaunch>,
    pub schedule: Schedule,
}

impl Plan {
    pub fn launches(&self) -> usize {
        self.kernels.len()
    }

    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }

    pub fn total_bytes(&self) -> f64 {
        self.kernels.iter().map(|k| k.bytes_total()).sum()
    }
}

/// Lower a graph under a schedule.  `fusion_depth` selects how many of
/// the graph's fusion opportunities are taken.
pub fn lower(g: &Graph, schedule: &Schedule) -> Plan {
    let plan = if schedule.fusion_depth == 0 {
        fusion::none(g)
    } else {
        fusion::partial(g, schedule.fusion_depth)
    };
    lower_with_plan(g, schedule, &plan)
}

/// Activation dependence per node: by convention input 0 is the
/// activation; all other inputs are parameters, constant across forward
/// passes.  A kernel whose nodes depend on no activation is
/// *precomputable* — real deployments hoist it to init (the paper's
/// §7.4 reduced program precomputes `W.sum(1)` into a buffer) — and is
/// excluded from the per-forward plan.
pub(crate) fn activation_dependent(g: &Graph) -> Vec<bool> {
    let mut dep = vec![false; g.nodes.len()];
    for (id, node) in g.nodes.iter().enumerate() {
        dep[id] = match &node.op {
            Op::Input { idx } => *idx == 0,
            _ => node.op.operands().iter().any(|&o| dep[o]),
        };
    }
    dep
}

/// Users adjacency: `users[n]` = ids of nodes that read n (replaces the
/// O(nodes^2) external-use scan that dominated lowering — §Perf).
pub(crate) fn node_users(g: &Graph) -> Vec<Vec<NodeId>> {
    let mut users: Vec<Vec<NodeId>> = vec![Vec::new(); g.nodes.len()];
    for (id, node) in g.nodes.iter().enumerate() {
        for o in node.op.operands() {
            users[o].push(id);
        }
    }
    users
}

/// Account one fusion group into a kernel launch.  Shared by
/// [`lower_with_plan`] and the oracle's dirty-region re-pricing, so an
/// incrementally rebuilt kernel is the same code path as a full
/// lowering — bit-identical by construction.
pub(crate) fn build_kernel(g: &Graph, users: &[Vec<NodeId>], members: Vec<NodeId>) -> KernelLaunch {
    let group: HashSet<NodeId> = members.iter().copied().collect();
    let mut flops = 0.0;
    let mut transcendental = 0.0;
    let mut bytes_read = 0.0;
    let mut bytes_written = 0.0;
    let mut class = KernelClass::Elementwise;
    let mut names = Vec::new();
    let mut out_elems = 0usize;
    let mut read_ids: HashSet<NodeId> = HashSet::new();
    for &id in &members {
        let node = &g.nodes[id];
        flops += node_flops(g, node);
        if let Op::Unary { kind, .. } = &node.op {
            if kind.is_transcendental() {
                transcendental += node.shape.numel() as f64;
            }
        }
        if matches!(node.op, Op::Softmax { .. } | Op::Layernorm { .. }) {
            transcendental += node.shape.numel() as f64;
        }
        names.push(node.op.mnemonic());
        class = dominant_class(class, class_of(&node.op));
        // external reads: operands outside the group, dedup per kernel
        for o in node.op.operands() {
            if !group.contains(&o) && read_ids.insert(o) {
                bytes_read += g.nodes[o].shape.bytes() as f64;
            }
        }
        // external writes: node used outside the group or is output
        let external_use =
            g.outputs.contains(&id) || users[id].iter().any(|u| !group.contains(u));
        if external_use {
            bytes_written += node.shape.bytes() as f64;
            out_elems = out_elems.max(node.shape.numel());
        }
    }
    KernelLaunch {
        nodes: members,
        name: names.join("+"),
        class,
        flops,
        transcendental_elems: transcendental,
        bytes_read,
        bytes_written,
        out_elems: out_elems.max(1),
    }
}

/// Lower with an explicit fusion plan (the baselines use this).
pub fn lower_with_plan(g: &Graph, schedule: &Schedule, fplan: &FusionPlan) -> Plan {
    let act_dep = activation_dependent(g);
    let users = node_users(g);
    let mut kernels = Vec::new();
    for members in fplan.members() {
        if members.is_empty() {
            continue;
        }
        // precomputable at init: skip in the per-forward plan
        if members.iter().all(|&id| !act_dep[id]) {
            continue;
        }
        kernels.push(build_kernel(g, &users, members));
    }
    Plan {
        kernels,
        schedule: schedule.clone(),
    }
}

fn class_of(op: &Op) -> KernelClass {
    match op {
        Op::Matmul { .. } | Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } => KernelClass::MatmulLike,
        Op::Attention { .. } => KernelClass::Attention,
        Op::Reduce { .. } | Op::Softmax { .. } | Op::Layernorm { .. } | Op::GlobalAvgPool { .. } => {
            KernelClass::Reduction
        }
        Op::Concat { .. } | Op::Transpose2 { .. } | Op::MaxPool2d { .. } | Op::AvgPool2d { .. } => {
            KernelClass::Movement
        }
        _ => KernelClass::Elementwise,
    }
}

/// Class precedence when fusing: the anchor wins.
fn dominant_class(a: KernelClass, b: KernelClass) -> KernelClass {
    use KernelClass::*;
    let rank = |c: KernelClass| match c {
        Attention => 4,
        MatmulLike => 3,
        Reduction => 2,
        Movement => 1,
        Elementwise => 0,
    };
    if rank(b) > rank(a) {
        b
    } else {
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::tensor::Shape;

    fn gemm_bias_relu() -> Graph {
        let mut b = GraphBuilder::new("gbr");
        let x = b.input(Shape::of(&[64, 64]));
        let w = b.input(Shape::of(&[64, 64]));
        let bias = b.input(Shape::of(&[64]));
        let m = b.matmul(x, w);
        let a = b.add(m, bias);
        let r = b.unary(UnaryKind::Relu, a);
        b.finish(vec![r])
    }

    #[test]
    fn eager_plan_three_kernels() {
        let g = gemm_bias_relu();
        let s = Schedule::naive();
        let p = lower(&g, &s);
        assert_eq!(p.launches(), 3);
    }

    #[test]
    fn fused_plan_one_kernel_less_traffic() {
        let g = gemm_bias_relu();
        let mut s = Schedule::naive();
        let eager = lower(&g, &s);
        s.fusion_depth = usize::MAX;
        let fused = lower(&g, &s);
        assert_eq!(fused.launches(), 1);
        assert!(fused.total_bytes() < eager.total_bytes());
        // flops identical — fusion moves bytes, not math
        assert!((fused.total_flops() - eager.total_flops()).abs() < 1.0);
    }

    #[test]
    fn fused_kernel_class_is_matmul() {
        let g = gemm_bias_relu();
        let mut s = Schedule::naive();
        s.fusion_depth = usize::MAX;
        let p = lower(&g, &s);
        assert_eq!(p.kernels[0].class, KernelClass::MatmulLike);
        assert!(p.kernels[0].name.contains("matmul"));
    }

    #[test]
    fn traffic_accounting_exact_for_fused_gemm() {
        let g = gemm_bias_relu();
        let mut s = Schedule::naive();
        s.fusion_depth = usize::MAX;
        let p = lower(&g, &s);
        let k = &p.kernels[0];
        // reads: x (64*64*4) + w (64*64*4) + bias (64*4)
        assert_eq!(k.bytes_read, (64.0 * 64.0 * 4.0) * 2.0 + 64.0 * 4.0);
        // writes: out 64*64*4 once
        assert_eq!(k.bytes_written, 64.0 * 64.0 * 4.0);
    }

    #[test]
    fn intensity_rises_with_fusion() {
        let g = gemm_bias_relu();
        let mut s = Schedule::naive();
        let eager = lower(&g, &s);
        s.fusion_depth = usize::MAX;
        let fused = lower(&g, &s);
        let ei: f64 = eager.total_flops() / eager.total_bytes();
        let fi: f64 = fused.total_flops() / fused.total_bytes();
        assert!(fi > ei);
    }
}
