//! Legality-filtered schedule move generators.
//!
//! Every candidate any strategy emits flows through these generators
//! (or is a field-wise recombination of schedules that did), and every
//! generator filters through [`legal::check`] against the target
//! [`PlatformSpec`] — so illegal schedules can never enter a search
//! population.  Crossover needs no re-check because schedule legality
//! is per-field (threadgroup shape, tile footprint, ept, vector width
//! are judged independently), so any field-wise mix of two legal
//! parents is legal; a test below pins that assumption against every
//! registered platform so a future coupled legality rule fails loudly
//! here instead of corrupting search populations silently.

use crate::platform::PlatformSpec;
use crate::sched::legal;
use crate::sched::schedule::{Lever, Schedule, Tile};
use crate::util::rng::Pcg;

/// Fusion depths worth distinguishing: eager, shallow partial takes,
/// and fully fused.  (Depths beyond a graph's opportunity count behave
/// like `full`, so a denser grid only duplicates plans.)
pub const FUSION_CHOICES: [usize; 5] = [0, 1, 2, 3, usize::MAX];
/// Elements-per-thread grid (legality caps at 16).
pub const EPT_CHOICES: [usize; 5] = [1, 2, 4, 8, 16];
/// Threadgroup sizes (filtered per platform by simd-width multiple and
/// device maximum).
pub const THREADGROUP_CHOICES: [usize; 5] = [64, 128, 256, 512, 1024];
/// Vector load widths (legality caps at 8).
pub const VEC_CHOICES: [usize; 4] = [1, 2, 4, 8];

/// All alternative values of one lever from `base`, legality-filtered,
/// `base` itself excluded, in declaration order (deterministic).
pub fn lever_values(spec: &PlatformSpec, base: &Schedule, lever: Lever) -> Vec<Schedule> {
    let mut out: Vec<Schedule> = Vec::new();
    let mut push = |cand: Schedule| {
        if cand != *base && legal::check(&cand, spec).is_ok() && !out.contains(&cand) {
            out.push(cand);
        }
    };
    match lever {
        Lever::Fusion => {
            for v in FUSION_CHOICES {
                let mut c = base.clone();
                c.fusion_depth = v;
                push(c);
            }
        }
        Lever::Tile => {
            for t in Tile::CHOICES {
                let mut c = base.clone();
                c.tile = t;
                push(c);
            }
        }
        Lever::Ept => {
            for v in EPT_CHOICES {
                let mut c = base.clone();
                c.ept = v;
                push(c);
            }
        }
        Lever::Threadgroup => {
            for v in THREADGROUP_CHOICES {
                let mut c = base.clone();
                c.threadgroup = v;
                push(c);
            }
        }
        Lever::FastMath => {
            let mut c = base.clone();
            c.fast_math = !c.fast_math;
            push(c);
        }
        Lever::Graphs => {
            let mut c = base.clone();
            c.use_graphs = !c.use_graphs;
            push(c);
        }
        Lever::VecWidth => {
            for v in VEC_CHOICES {
                let mut c = base.clone();
                c.vec_width = v;
                push(c);
            }
        }
    }
    out
}

/// The full single-lever neighborhood of `base`: every legal move of
/// every lever, deduplicated, in lever-then-value order.
pub fn neighbors(base: &Schedule, spec: &PlatformSpec) -> Vec<Schedule> {
    let mut out: Vec<Schedule> = Vec::new();
    for lever in Lever::ALL {
        for cand in lever_values(spec, base, lever) {
            if !out.contains(&cand) {
                out.push(cand);
            }
        }
    }
    out
}

/// Draw a uniformly random legal schedule (evolutionary init).  Falls
/// back to naive if the (astronomically unlikely) retry budget runs
/// out — naive is legal on every registered platform.
pub fn random_legal(spec: &PlatformSpec, rng: &mut Pcg) -> Schedule {
    for _ in 0..64 {
        let s = Schedule {
            fusion_depth: *rng.choose(&FUSION_CHOICES),
            tile: *rng.choose(&Tile::CHOICES),
            ept: *rng.choose(&EPT_CHOICES),
            threadgroup: *rng.choose(&THREADGROUP_CHOICES),
            fast_math: rng.chance(0.5),
            use_graphs: rng.chance(0.5),
            vec_width: *rng.choose(&VEC_CHOICES),
        };
        if legal::check(&s, spec).is_ok() {
            return s;
        }
    }
    Schedule::naive()
}

/// Mutate one random lever of `base` to a random legal alternative.
/// Returns `base` unchanged only if no lever has any legal alternative
/// (impossible on the registered platforms — fast-math always toggles).
pub fn mutate(base: &Schedule, spec: &PlatformSpec, rng: &mut Pcg) -> Schedule {
    for _ in 0..16 {
        let lever = *rng.choose(&Lever::ALL);
        let opts = lever_values(spec, base, lever);
        if !opts.is_empty() {
            return opts[rng.below(opts.len() as u32) as usize].clone();
        }
    }
    base.clone()
}

/// Field-wise crossover of two legal parents (uniform mask).  Legal by
/// construction — see the module docs and the pin test below.
pub fn crossover(a: &Schedule, b: &Schedule, rng: &mut Pcg) -> Schedule {
    let mut s = a.clone();
    if rng.chance(0.5) {
        s.fusion_depth = b.fusion_depth;
    }
    if rng.chance(0.5) {
        s.tile = b.tile;
    }
    if rng.chance(0.5) {
        s.ept = b.ept;
    }
    if rng.chance(0.5) {
        s.threadgroup = b.threadgroup;
    }
    if rng.chance(0.5) {
        s.fast_math = b.fast_math;
    }
    if rng.chance(0.5) {
        s.use_graphs = b.use_graphs;
    }
    if rng.chance(0.5) {
        s.vec_width = b.vec_width;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::registry;

    #[test]
    fn neighborhoods_are_legal_nonempty_and_deterministic() {
        for platform in registry().platforms() {
            let spec = platform.spec();
            for base in [Schedule::naive(), platform.expert_schedule()] {
                let ns = neighbors(&base, spec);
                assert!(!ns.is_empty(), "{}: empty neighborhood", platform.name());
                assert_eq!(ns, neighbors(&base, spec), "{}", platform.name());
                for n in &ns {
                    assert_ne!(*n, base);
                    legal::check(n, spec)
                        .unwrap_or_else(|e| panic!("{}: illegal neighbor {}: {e}", platform.name(), n.canon()));
                }
                // no duplicates
                let mut keys: Vec<String> = ns.iter().map(|s| s.canon()).collect();
                let total = keys.len();
                keys.sort();
                keys.dedup();
                assert_eq!(keys.len(), total, "{}: duplicate neighbors", platform.name());
            }
        }
    }

    #[test]
    fn metal_tile_neighborhood_is_onchip_filtered() {
        // 32 KiB of threadgroup memory excludes the 128-wide tiles
        let spec = crate::platform::metal::m4_max();
        let tiles = lever_values(&spec, &Schedule::naive(), Lever::Tile);
        assert!(!tiles.is_empty());
        for t in &tiles {
            assert!(t.tile.onchip_bytes() <= spec.onchip_bytes);
            assert!(t.tile.bm < 128, "oversized tile {} survived the filter", t.canon());
        }
    }

    #[test]
    fn random_legal_and_mutate_stay_legal_on_every_platform() {
        for platform in registry().platforms() {
            let spec = platform.spec();
            let mut rng = Pcg::seed(0xF17E | crate::util::rng::fnv1a(platform.name().as_bytes()));
            let mut s = Schedule::naive();
            for _ in 0..200 {
                let r = random_legal(spec, &mut rng);
                legal::check(&r, spec).unwrap();
                s = mutate(&s, spec, &mut rng);
                legal::check(&s, spec).unwrap();
            }
        }
    }

    #[test]
    fn crossover_of_legal_parents_is_legal_per_field() {
        // the assumption crossover rests on: legality is per-field, so
        // any field-wise mix of legal parents is legal.  Pin it by
        // exhaustively mixing random legal parents on every platform.
        for platform in registry().platforms() {
            let spec = platform.spec();
            let mut rng = Pcg::seed(0xC0550);
            for _ in 0..300 {
                let a = random_legal(spec, &mut rng);
                let b = random_legal(spec, &mut rng);
                let c = crossover(&a, &b, &mut rng);
                legal::check(&c, spec).unwrap_or_else(|e| {
                    panic!(
                        "{}: crossover of legal parents produced illegal child {} (parents {} / {}): {e}",
                        platform.name(),
                        c.canon(),
                        a.canon(),
                        b.canon()
                    )
                });
            }
        }
    }
}
