//! Evolutionary search: mutation + crossover over `Schedule` fields.
//!
//! A rank-selected population: seeds plus random legal schedules, then
//! rounds of elite survival, uniform field-wise crossover of
//! rank-biased parents ([`super::neighbors::crossover`]) and one-lever
//! mutation ([`super::neighbors::mutate`]).  All randomness comes from
//! the seeded `Pcg` the caller supplies and is drawn only on the
//! calling thread — evaluation fans out across the worker pool, so the
//! result is bit-identical for any worker count.  Explores lever
//! *combinations* beam search's single-lever moves reach only
//! step-by-step, at the price of noisier convergence.

use super::neighbors;
use super::{score_batch, seed_points, sort_frontier, Budget, CostOracle, SearchOutcome, SearchStrategy};
use crate::util::rng::Pcg;
use std::collections::BTreeSet;

/// Evolutionary strategy: `population` individuals per generation,
/// the best `elite` surviving unchanged.
#[derive(Debug, Clone)]
pub struct EvolveStrategy {
    pub population: usize,
    pub elite: usize,
}

impl Default for EvolveStrategy {
    fn default() -> EvolveStrategy {
        EvolveStrategy { population: 16, elite: 4 }
    }
}

/// Rank-biased parent pick: the better of two uniform draws.
fn pick_rank(rng: &mut Pcg, n: usize) -> usize {
    let a = rng.below(n as u32) as usize;
    let b = rng.below(n as u32) as usize;
    a.min(b)
}

impl SearchStrategy for EvolveStrategy {
    fn name(&self) -> &'static str {
        "evolve"
    }

    fn describe(&self) -> &'static str {
        "evolutionary search: rank selection, field-wise crossover, one-lever mutation"
    }

    fn search(&self, oracle: &CostOracle<'_>, budget: &mut Budget, rng: &mut Pcg) -> SearchOutcome {
        let spec = oracle.spec();
        let population = self.population.max(2);
        let elite = self.elite.clamp(1, population - 1);
        let mut visited = Vec::new();

        let mut init = seed_points(oracle);
        // global membership set: a schedule scored in any generation is
        // never re-priced, so the whole budget buys new points
        // (membership-only — order never read, determinism holds)
        let mut seen: BTreeSet<String> = init.iter().map(|s| s.canon()).collect();
        let mut attempts = 0;
        while init.len() < population && attempts < population * 8 {
            attempts += 1;
            let cand = neighbors::random_legal(spec, rng);
            if seen.insert(cand.canon()) {
                init.push(cand);
            }
        }
        let mut pop = score_batch(oracle, budget, init, &mut visited);
        sort_frontier(&mut pop);
        if let Some(head) = pop.first() {
            budget.observe(head.cost_s);
        }

        while budget.should_continue() && !pop.is_empty() {
            let target = population.saturating_sub(elite.min(pop.len()));
            let mut children: Vec<crate::sched::Schedule> = Vec::new();
            let mut tries = 0;
            while children.len() < target && tries < target * 8 {
                tries += 1;
                let pa = &pop[pick_rank(rng, pop.len())].schedule;
                let pb = &pop[pick_rank(rng, pop.len())].schedule;
                let mut child = neighbors::crossover(pa, pb, rng);
                if rng.chance(0.6) {
                    child = neighbors::mutate(&child, spec, rng);
                }
                if seen.insert(child.canon()) {
                    children.push(child);
                }
            }
            if children.is_empty() {
                break; // the reachable space around this population is exhausted
            }
            let scored = score_batch(oracle, budget, children, &mut visited);
            if scored.is_empty() {
                break; // budget exhausted mid-generation
            }
            let mut next: Vec<super::Scored> = pop.iter().take(elite).cloned().collect();
            next.extend(scored);
            sort_frontier(&mut next);
            next.truncate(population);
            let round_best = next[0].cost_s;
            pop = next;
            if !budget.observe(round_best) {
                break;
            }
        }

        oracle.rerank(&mut pop);
        pop.truncate(8); // frontier worth reporting, not the whole population
        let best = pop.first().cloned().unwrap_or_else(|| super::Scored {
            schedule: crate::sched::Schedule::naive(),
            cost_s: f64::INFINITY,
        });
        SearchOutcome { best, frontier: pop, visited }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::registry;
    use crate::sched::{legal, Schedule};
    use crate::workloads::Suite;

    #[test]
    fn evolve_improves_on_naive_for_every_platform_and_stays_legal() {
        let suite = Suite::sample(1);
        let problem = &suite.problems[0];
        for platform in registry().platforms() {
            let spec = platform.spec();
            if !problem.supported_on(spec) {
                continue;
            }
            let oracle = CostOracle::new(spec, &problem.perf_graph);
            let naive = oracle.cost(&Schedule::naive());
            let mut budget = Budget::new(200, 3);
            let mut rng = Pcg::seed(7);
            let out = EvolveStrategy::default().search(&oracle, &mut budget, &mut rng);
            assert!(
                out.best.cost_s <= naive,
                "{}: evolve {} worse than naive {naive}",
                platform.name(),
                out.best.cost_s
            );
            for s in &out.visited {
                legal::check(s, spec).unwrap_or_else(|e| {
                    panic!("{}: evolve visited illegal {}: {e}", platform.name(), s.canon())
                });
            }
        }
    }

    #[test]
    fn evolve_is_seed_deterministic_and_worker_invariant() {
        let suite = Suite::sample(1);
        let problem = &suite.problems[0];
        let spec = crate::platform::cuda::h100();
        let run = |workers: usize, seed: u64| {
            let oracle = CostOracle::new(&spec, &problem.perf_graph).with_workers(workers);
            let mut budget = Budget::new(120, 2);
            let mut rng = Pcg::seed(seed);
            EvolveStrategy::default().search(&oracle, &mut budget, &mut rng)
        };
        let a = run(1, 11);
        let b = run(16, 11);
        assert_eq!(a.visited, b.visited);
        assert_eq!(a.best.schedule, b.best.schedule);
        assert_eq!(a.best.cost_s.to_bits(), b.best.cost_s.to_bits());
        // a different seed explores a different trajectory
        let c = run(1, 12);
        assert!(a.visited != c.visited, "seed should steer the population");
    }
}
