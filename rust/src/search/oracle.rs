//! The cost oracle: how search candidates are ranked.
//!
//! A candidate's primary score is [`crate::perfsim::ideal_time`] — the
//! noise-free model time of its lowered plan.  The oracle is a pure
//! function of (spec, graph, schedule), which is what lets populations
//! fan out across the worker pool with no effect on results, and what
//! makes a seeded search bit-identical across worker counts.
//!
//! Optionally the oracle re-ranks *near-tied* frontier points using
//! profiler [`Evidence`](crate::profiler::Evidence) from the platform's
//! registered frontend: when two schedules price within [`REL_EPS`] of
//! each other, prefer the one whose interpreted evidence shows less
//! launch pressure, then higher worst-kernel occupancy — the same
//! facts the analysis agent ranks recommendations from, consumed
//! through the same frontend-neutral IR (never the capture format).

use super::Scored;
use crate::coordinator::worker;
use crate::kir::patch::DirtySet;
use crate::obs;
use crate::kir::rewrite::fusion::{self, FusionPlan};
use crate::kir::Graph;
use crate::perfsim::lower::{self as lower_mod, lower, KernelLaunch, Plan};
use crate::perfsim::{self, cost, exec};
use crate::platform::PlatformSpec;
use crate::profiler::{Profile, ProfilerFrontendRef};
use crate::sched::{legal, Schedule};
use crate::util::rng::Pcg;

/// Relative cost window within which evidence may reorder the frontier.
pub const REL_EPS: f64 = 0.005;

/// A priced schedule with its lowered artifacts retained, so a later
/// [`reprice`] against a patched graph can rebuild only the dirty
/// region's timeline contribution.  `cost_s` is bit-identical to what
/// [`CostOracle::cost`] returns for the same (spec, graph, schedule) —
/// the incremental path shares every costing statement with the full
/// path and is differentially tested against it.
pub struct PricedPlan {
    /// Noise-free model seconds (infinite for illegal schedules).
    pub cost_s: f64,
    /// Kernels whose body cost was reused rather than recomputed —
    /// zero for a fresh [`price`], the whole point of [`reprice`].
    pub reused_kernels: usize,
    plan: Plan,
    fplan: FusionPlan,
    bodies: Vec<f64>,
    /// Kernel index per node id (None: node emits no priced kernel).
    kernel_of: Vec<Option<usize>>,
}

fn fplan_for(g: &Graph, s: &Schedule) -> FusionPlan {
    if s.fusion_depth == 0 {
        fusion::none(g)
    } else {
        fusion::partial(g, s.fusion_depth)
    }
}

fn finish_price(
    spec: &PlatformSpec,
    s: &Schedule,
    g: &Graph,
    fplan: FusionPlan,
    kernels: Vec<KernelLaunch>,
    bodies: Vec<f64>,
    reused_kernels: usize,
) -> PricedPlan {
    let mut kernel_of: Vec<Option<usize>> = vec![None; g.nodes.len()];
    for (ki, k) in kernels.iter().enumerate() {
        for &id in &k.nodes {
            kernel_of[id] = Some(ki);
        }
    }
    let cost_s = if legal::check(s, spec).is_err() {
        f64::INFINITY
    } else {
        exec::ideal_from_bodies(spec, s, &bodies)
    };
    PricedPlan {
        cost_s,
        reused_kernels,
        plan: Plan { kernels, schedule: s.clone() },
        fplan,
        bodies,
        kernel_of,
    }
}

/// Fully price one (graph, schedule), keeping the lowered artifacts
/// for later incremental re-pricing.
pub fn price(spec: &PlatformSpec, g: &Graph, s: &Schedule) -> PricedPlan {
    obs::counter("oracle.price", 1);
    let fplan = fplan_for(g, s);
    let plan = lower_mod::lower_with_plan(g, s, &fplan);
    let bodies: Vec<f64> = plan
        .kernels
        .iter()
        .map(|k| cost::kernel_cost(spec, s, k).total_s)
        .collect();
    finish_price(spec, s, g, fplan, plan.kernels, bodies, 0)
}

/// Re-price a patched graph, rebuilding only what the patch dirtied.
///
/// A kernel from `prev` is reused when every member of the new fusion
/// group is clean under `dirty` and the group's preimage (old ids) is
/// exactly the member set of one previous kernel — the dirty rules
/// guarantee op content, operand shapes, user sets, and output
/// membership are unchanged there, so its accounted cost is the same
/// bits [`lower_mod::build_kernel`] + `kernel_cost` would recompute.
/// Everything else (including the launch-count-dependent dispatch fold)
/// is recomputed, so the result is bit-identical to a full [`price`] of
/// the patched graph.  Falls back to a full price when the schedule
/// differs from the one `prev` was priced under or the dirty set is for
/// another graph.
pub fn reprice(
    spec: &PlatformSpec,
    s: &Schedule,
    prev: &PricedPlan,
    g: &Graph,
    dirty: &DirtySet,
) -> PricedPlan {
    obs::counter("oracle.reprice", 1);
    if prev.plan.schedule != *s || dirty.len() != g.nodes.len() {
        return price(spec, g, s);
    }
    let fplan = if s.fusion_depth == 0 {
        fusion::none(g)
    } else if s.fusion_depth == usize::MAX {
        fusion::greedy_refresh(g, &prev.fplan, dirty)
    } else {
        // partial(k) counts opportunities globally; recompute it whole
        fusion::partial(g, s.fusion_depth)
    };
    let act_dep = lower_mod::activation_dependent(g);
    let users = lower_mod::node_users(g);
    // invert the patch's old→new id map
    let mut new_to_old: Vec<Option<usize>> = vec![None; g.nodes.len()];
    for (old, m) in dirty.old_to_new.iter().enumerate() {
        if let Some(new) = *m {
            if new < new_to_old.len() {
                new_to_old[new] = Some(old);
            }
        }
    }
    let mut kernels: Vec<KernelLaunch> = Vec::new();
    let mut bodies: Vec<f64> = Vec::new();
    let mut reused_kernels = 0usize;
    for members in fplan.members() {
        if members.is_empty() {
            continue;
        }
        // precomputable at init: skip in the per-forward plan
        if members.iter().all(|&id| !act_dep[id]) {
            continue;
        }
        let mut reuse: Option<usize> = None;
        if members.iter().all(|&id| !dirty.is_dirty(id) && new_to_old[id].is_some()) {
            let olds: Vec<usize> =
                members.iter().map(|&id| new_to_old[id].unwrap()).collect();
            if let Some(Some(ki)) = prev.kernel_of.get(olds[0]).copied() {
                if prev.plan.kernels[ki].nodes == olds {
                    reuse = Some(ki);
                }
            }
        }
        match reuse {
            Some(ki) => {
                let mut k = prev.plan.kernels[ki].clone();
                k.nodes = members;
                bodies.push(prev.bodies[ki]);
                kernels.push(k);
                reused_kernels += 1;
            }
            None => {
                let k = lower_mod::build_kernel(g, &users, members);
                bodies.push(cost::kernel_cost(spec, s, &k).total_s);
                kernels.push(k);
            }
        }
    }
    obs::counter("oracle.reused_kernels", reused_kernels as u64);
    finish_price(spec, s, g, fplan, kernels, bodies, reused_kernels)
}

/// Pure candidate-pricing context for one (platform spec, perf graph).
pub struct CostOracle<'a> {
    spec: &'a PlatformSpec,
    graph: &'a Graph,
    frontend: Option<ProfilerFrontendRef>,
    workers: usize,
    transfer_seeds: Vec<Schedule>,
}

impl<'a> CostOracle<'a> {
    pub fn new(spec: &'a PlatformSpec, graph: &'a Graph) -> CostOracle<'a> {
        CostOracle { spec, graph, frontend: None, workers: 1, transfer_seeds: Vec::new() }
    }

    /// Fan batch evaluations across `n` worker threads (values are
    /// unchanged by construction — evaluation is pure).
    pub fn with_workers(mut self, n: usize) -> CostOracle<'a> {
        self.workers = n.max(1);
        self
    }

    /// Enable evidence re-ranking through a profiler frontend.
    pub fn with_evidence(mut self, frontend: ProfilerFrontendRef) -> CostOracle<'a> {
        self.frontend = Some(frontend);
        self
    }

    /// Extra starting points for the search, transferred from tuned
    /// schedules of structurally similar graphs (same
    /// [`crate::store::key::family_fingerprint`]).  Strategies fold
    /// them into [`super::seed_points`] after legality filtering and
    /// dedup — an illegal or duplicate donor is silently dropped, so
    /// transfer can only add candidates, never replace the naive seed.
    pub fn with_transfer_seeds(mut self, seeds: Vec<Schedule>) -> CostOracle<'a> {
        self.transfer_seeds = seeds;
        self
    }

    pub fn transfer_seeds(&self) -> &[Schedule] {
        &self.transfer_seeds
    }

    pub fn spec(&self) -> &PlatformSpec {
        self.spec
    }

    /// Noise-free simulated seconds for one schedule; illegal
    /// schedules price at infinity (strategies filter them out before
    /// ever reaching here — this is the belt to that suspenders).
    pub fn cost(&self, s: &Schedule) -> f64 {
        // counted per evaluation wherever it runs (caller thread or
        // pool); integer counters sum order-independently, so the
        // total is worker-count invariant like the values themselves
        obs::counter("oracle.evaluations", 1);
        if legal::check(s, self.spec).is_err() {
            return f64::INFINITY;
        }
        perfsim::ideal_time(self.spec, &lower(self.graph, s))
    }

    /// Price one schedule keeping the lowered artifacts, so callers
    /// holding a [`GraphPatch`](crate::kir::patch::GraphPatch) result
    /// can [`reprice`] instead of re-lowering from scratch.  The
    /// returned `cost_s` is bit-identical to [`CostOracle::cost`].
    pub fn price(&self, s: &Schedule) -> PricedPlan {
        price(self.spec, self.graph, s)
    }

    /// Price a population, fanned out across the worker pool.  Results
    /// are in candidate order regardless of scheduling.
    pub fn cost_many(&self, cands: &[Schedule]) -> Vec<f64> {
        if cands.len() <= 1 || self.workers <= 1 {
            return cands.iter().map(|s| self.cost(s)).collect();
        }
        worker::run_jobs(self.workers, cands, |s| self.cost(s))
    }

    /// Evidence facts for one schedule: (launch-time fraction, minimum
    /// per-kernel occupancy) as the platform's frontend interpreted
    /// them.  An uninterpretable capture ranks worst — the oracle will
    /// not prefer a schedule on evidence it cannot read.
    fn evidence_facts(&self, s: &Schedule) -> (f64, f64) {
        let Some(frontend) = &self.frontend else {
            return (f64::INFINITY, 0.0);
        };
        let plan = lower(self.graph, s);
        // the simulation is only rendered into a profile; ideal-path
        // facts do not depend on the measurement RNG
        let sim = perfsim::simulate(self.spec, &plan, &mut Pcg::seed(0), 1, 0);
        let profile = Profile::from_sim("search", self.spec.name, &sim);
        match frontend.evidence(&profile) {
            Ok(ev) => (ev.launch_fraction().or(1.0), ev.min_occupancy().or(0.0)),
            Err(_) => (f64::INFINITY, 0.0),
        }
    }

    /// Deterministically re-rank the leading near-tied group of a
    /// sorted frontier by interpreted evidence.  A no-op without a
    /// frontend, on frontiers shorter than two, or when the cost gap
    /// at the top already exceeds [`REL_EPS`].
    pub fn rerank(&self, frontier: &mut [Scored]) {
        if self.frontend.is_none() || frontier.len() < 2 {
            return;
        }
        let best = frontier[0].cost_s;
        if !best.is_finite() {
            return;
        }
        let near = frontier
            .iter()
            .take_while(|s| s.cost_s <= best * (1.0 + REL_EPS))
            .count();
        if near < 2 {
            return;
        }
        obs::counter("oracle.rerank.evidence", near as u64);
        let mut head: Vec<(Scored, f64, f64)> = frontier[..near]
            .iter()
            .map(|s| {
                let (launch, occ) = self.evidence_facts(&s.schedule);
                (s.clone(), launch, occ)
            })
            .collect();
        head.sort_by(|a, b| {
            a.1.total_cmp(&b.1) // less launch pressure first
                .then_with(|| b.2.total_cmp(&a.2)) // then higher occupancy
                .then_with(|| a.0.cost_s.total_cmp(&b.0.cost_s))
                .then_with(|| a.0.schedule.canon().cmp(&b.0.schedule.canon()))
        });
        for (slot, (scored, _, _)) in frontier[..near].iter_mut().zip(head) {
            *slot = scored;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::UnaryKind;
    use crate::platform::{by_name, cuda};
    use crate::tensor::Shape;

    fn graph(dim: usize) -> Graph {
        let mut b = GraphBuilder::new("oracle");
        let x = b.input(Shape::of(&[dim, dim]));
        let w = b.input(Shape::of(&[dim, dim]));
        let m = b.matmul(x, w);
        let r = b.unary(UnaryKind::Swish, m);
        b.finish(vec![r])
    }

    #[test]
    fn cost_is_pure_and_ranks_expert_at_or_below_naive() {
        let spec = cuda::h100();
        let g = graph(256);
        let oracle = CostOracle::new(&spec, &g);
        let naive = oracle.cost(&Schedule::naive());
        assert_eq!(naive.to_bits(), oracle.cost(&Schedule::naive()).to_bits());
        let expert = oracle.cost(&Schedule::expert_for(&spec));
        assert!(expert <= naive, "expert {expert} naive {naive}");
        assert!(naive.is_finite() && naive > 0.0);
    }

    #[test]
    fn illegal_schedules_price_at_infinity() {
        let spec = cuda::h100();
        let g = graph(64);
        let oracle = CostOracle::new(&spec, &g);
        let mut bad = Schedule::naive();
        bad.threadgroup = 2048;
        assert!(oracle.cost(&bad).is_infinite());
    }

    #[test]
    fn cost_many_is_worker_count_invariant() {
        let spec = cuda::h100();
        let g = graph(128);
        let cands: Vec<Schedule> =
            super::super::neighbors::neighbors(&Schedule::naive(), &spec);
        assert!(cands.len() > 4);
        let one = CostOracle::new(&spec, &g).with_workers(1).cost_many(&cands);
        let many = CostOracle::new(&spec, &g).with_workers(8).cost_many(&cands);
        assert_eq!(one.len(), many.len());
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn price_matches_cost_bitwise() {
        let spec = cuda::h100();
        let g = graph(64);
        let oracle = CostOracle::new(&spec, &g);
        for s in [Schedule::naive(), Schedule::expert_for(&spec)] {
            assert_eq!(
                oracle.price(&s).cost_s.to_bits(),
                oracle.cost(&s).to_bits(),
                "{}",
                s.canon()
            );
        }
        let mut bad = Schedule::naive();
        bad.threadgroup = 2048;
        assert!(oracle.price(&bad).cost_s.is_infinite());
    }

    #[test]
    fn reprice_after_patch_matches_full_price() {
        use crate::kir::op::Op;
        use crate::kir::patch::GraphPatch;
        let spec = cuda::h100();
        let g = graph(64);
        let swish = g
            .nodes
            .iter()
            .position(|n| matches!(n.op, Op::Unary { .. }))
            .unwrap();
        let mm = g
            .nodes
            .iter()
            .position(|n| matches!(n.op, Op::Matmul { .. }))
            .unwrap();
        for s in [Schedule::naive(), Schedule::expert_for(&spec)] {
            let prev = price(&spec, &g, &s);
            let mut p = GraphPatch::new(&g);
            p.prune();
            p.redirect(swish, mm).unwrap(); // bypass the epilogue
            let (g2, dirty) = p.apply().unwrap();
            let inc = reprice(&spec, &s, &prev, &g2, &dirty);
            let full = price(&spec, &g2, &s);
            assert_eq!(
                inc.cost_s.to_bits(),
                full.cost_s.to_bits(),
                "{}",
                s.canon()
            );
            assert_eq!(
                inc.cost_s.to_bits(),
                CostOracle::new(&spec, &g2).cost(&s).to_bits()
            );
        }
    }

    #[test]
    fn rerank_prefers_lower_launch_pressure_among_near_ties() {
        // a launch-heavy eager schedule vs the same with graphs on:
        // force a near-tie by lying about the costs, then check the
        // evidence re-rank puts the graphs-on schedule first
        let platform = by_name("cuda").unwrap();
        let spec = platform.spec().clone();
        let g = graph(32);
        let oracle =
            CostOracle::new(&spec, &g).with_evidence(platform.profiler_frontend());
        let eager = Schedule::naive();
        let mut graphs_on = Schedule::naive();
        graphs_on.use_graphs = true;
        let mut frontier = vec![
            Scored { schedule: eager.clone(), cost_s: 1.0 },
            Scored { schedule: graphs_on.clone(), cost_s: 1.0 },
        ];
        oracle.rerank(&mut frontier);
        assert_eq!(frontier[0].schedule, graphs_on, "evidence should break the tie");
        // deterministic: a second pass leaves the order unchanged
        let before: Vec<String> = frontier.iter().map(|s| s.schedule.canon()).collect();
        oracle.rerank(&mut frontier);
        let after: Vec<String> = frontier.iter().map(|s| s.schedule.canon()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn rerank_is_a_noop_without_a_frontend_or_beyond_the_window() {
        let spec = cuda::h100();
        let g = graph(32);
        let plain = CostOracle::new(&spec, &g);
        let mut frontier = vec![
            Scored { schedule: Schedule::naive(), cost_s: 1.0 },
            Scored { schedule: Schedule::expert_for(&spec), cost_s: 1.0001 },
        ];
        let before: Vec<String> = frontier.iter().map(|s| s.schedule.canon()).collect();
        plain.rerank(&mut frontier);
        let after: Vec<String> = frontier.iter().map(|s| s.schedule.canon()).collect();
        assert_eq!(before, after);
        // with a frontend but a wide cost gap, order is also preserved
        let platform = by_name("cuda").unwrap();
        let ev = CostOracle::new(&spec, &g).with_evidence(platform.profiler_frontend());
        let mut gapped = vec![
            Scored { schedule: Schedule::naive(), cost_s: 1.0 },
            Scored { schedule: Schedule::expert_for(&spec), cost_s: 2.0 },
        ];
        ev.rerank(&mut gapped);
        assert_eq!(gapped[0].schedule, Schedule::naive());
    }
}
