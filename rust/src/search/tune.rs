//! The tune driver: run a strategy over a suite, store-cached.
//!
//! One [`TuneOutcome`] per (platform, strategy, problem) is cached in
//! the process result store under its own `kforge-tunekey` key kind:
//! schema version + the compile-time pipeline fingerprint + the full
//! platform spec hash + frontend + strategy/budget/patience/seed/
//! evidence knobs + the perf-graph structural hash.  Worker count is
//! deliberately excluded — candidate evaluation is pure, so pool size
//! never changes a result (property-pinned in `rust/tests/store.rs`).
//!
//! Serialization is bit-exact: the three cost f64s are stored as
//! IEEE-754 bit patterns and the schedule as its all-integer canonical
//! line, so a warm `search_frontier_*` render is byte-identical to a
//! cold one — the same guarantee campaign results carry.

use super::{strategy_by_name, Budget, CostOracle, StrategyRef};
use crate::platform::PlatformRef;
use crate::sched::Schedule;
use crate::store::{self, key as storekey, CacheStats, JobKey, Store, STORE_SCHEMA};
use crate::util::rng::{fnv1a, Pcg};
use crate::util::stats;
use crate::workloads::{Problem, Suite};
use anyhow::{bail, Context, Result};

/// Magic first line of every tune key — what keeps this key kind
/// textually disjoint from job keys.
pub const TUNE_MAGIC: &str = "kforge-tunekey v1";

const TUNE_RESULT_END: &str = "end kforge-tune-result";

/// One autotuning run: platform, strategy and budget knobs.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    pub platform: PlatformRef,
    pub strategy: StrategyRef,
    /// Max oracle evaluations per problem.
    pub budget: usize,
    /// Early-stop after this many stale rounds.
    pub patience: usize,
    pub seed: u64,
    /// Worker threads for candidate evaluation (never affects results).
    pub workers: usize,
    /// Re-rank near-tied frontiers with profiler `Evidence` from the
    /// platform's registered frontend.
    pub use_evidence: bool,
    /// Seed each problem's search population with the tuned schedule
    /// of a structurally similar problem (same
    /// [`storekey::family_fingerprint`]) already tuned this run or
    /// already in the store.  Donors are legality-filtered extra seeds
    /// only — the naive floor is untouched, so transfer can never make
    /// `tuned_s` worse than naive.
    pub use_transfer: bool,
}

impl TuneConfig {
    /// Defaults: beam strategy, the platform's worker-pool size,
    /// evidence re-rank on, cross-problem transfer on.
    pub fn new(platform: PlatformRef) -> TuneConfig {
        TuneConfig {
            workers: platform.default_workers(),
            platform,
            strategy: strategy_by_name("beam").expect("builtin beam strategy"),
            budget: 160,
            patience: 3,
            seed: 0x7E5E,
            use_evidence: true,
            use_transfer: true,
        }
    }
}

/// The autotuner's verdict on one problem.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub problem_id: String,
    pub strategy: &'static str,
    /// Noise-free simulated seconds of the naive schedule.
    pub naive_s: f64,
    /// ... of the platform's expert schedule.
    pub expert_s: f64,
    /// ... of the best schedule search found (≤ `naive_s` always —
    /// naive seeds every population, with an explicit fallback).
    pub tuned_s: f64,
    pub schedule: Schedule,
    /// Oracle evaluations spent.
    pub evals: usize,
    /// 1-based position (in evaluation order) of the first scoring of
    /// the winning schedule — the evaluations-to-frontier number the
    /// transfer measurement in `search_frontier_*` reports.
    pub evals_to_best: usize,
    /// Transfer seeds actually injected into the search population
    /// (legal, deduplicated donors; 0 with transfer off or no family
    /// mate available).
    pub seeded: usize,
}

impl TuneOutcome {
    pub fn speedup_vs_naive(&self) -> f64 {
        self.naive_s / self.tuned_s.max(1e-300)
    }

    pub fn le_naive(&self) -> bool {
        self.tuned_s <= self.naive_s
    }

    pub fn le_expert(&self) -> bool {
        self.tuned_s <= self.expert_s
    }
}

/// A full tune run over a suite.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub platform: &'static str,
    pub strategy: &'static str,
    pub outcomes: Vec<TuneOutcome>,
    /// Tune-cache counters for this run (all zeros when the store is
    /// disabled, mirroring campaign semantics).
    pub cache: CacheStats,
}

impl TuneReport {
    pub fn count_le_naive(&self) -> usize {
        self.outcomes.iter().filter(|o| o.le_naive()).count()
    }

    pub fn count_le_expert(&self) -> usize {
        self.outcomes.iter().filter(|o| o.le_expert()).count()
    }

    /// The printed, golden-pinned acceptance lines: the ≤naive and
    /// ≤expert fractions plus the geomean speedup over naive.
    pub fn summary(&self) -> String {
        let n = self.outcomes.len();
        if n == 0 {
            return "no problems tuned (suite empty after the platform filter)\n".to_string();
        }
        let speedups: Vec<f64> = self.outcomes.iter().map(|o| o.speedup_vs_naive()).collect();
        format!(
            "autotuned<=naive: {}/{} ({:.1}%)\nautotuned<=expert: {}/{} ({:.1}%)\ngeomean speedup vs naive: {:.3}x\n",
            self.count_le_naive(),
            n,
            100.0 * self.count_le_naive() as f64 / n as f64,
            self.count_le_expert(),
            n,
            100.0 * self.count_le_expert() as f64 / n as f64,
            stats::geomean(&speedups),
        )
    }
}

/// The canonical tune key for one (config, problem).
pub fn tune_key(cfg: &TuneConfig, problem: &Problem) -> JobKey {
    let spec = cfg.platform.spec();
    let text = format!(
        "{TUNE_MAGIC}\nschema {}\npipeline {:016x}\nplatform {} spec {:016x} frontend {}\nstrategy {} budget {} patience {} seed {:016x} evidence {} transfer {}\nproblem {} level {:?} perf {:016x}",
        STORE_SCHEMA,
        storekey::pipeline_fingerprint(),
        cfg.platform.name(),
        storekey::spec_hash(spec),
        cfg.platform.profiler_frontend().name(),
        cfg.strategy.name(),
        cfg.budget,
        cfg.patience,
        cfg.seed,
        cfg.use_evidence,
        cfg.use_transfer,
        problem.id,
        problem.level,
        storekey::graph_fingerprint(&problem.perf_graph),
    );
    JobKey::from_text(text)
}

/// Magic first line of every family key — the cross-problem transfer
/// index.  One blob per (tune knobs, schedule family) holds the first
/// tuned schedule seen for that family, as its canonical line.
pub const FAMILY_MAGIC: &str = "kforge-famkey v1";

/// The store key under which a family's donor schedule lives.  Covers
/// the same knobs as [`tune_key`] minus the problem identity (the
/// family hash replaces it), so donors never leak across strategies,
/// budgets, seeds or platforms.
pub fn family_key(cfg: &TuneConfig, family: u64) -> JobKey {
    let spec = cfg.platform.spec();
    let text = format!(
        "{FAMILY_MAGIC}\nschema {}\npipeline {:016x}\nplatform {} spec {:016x} frontend {}\nstrategy {} budget {} patience {} seed {:016x} evidence {}\nfamily {:016x}",
        STORE_SCHEMA,
        storekey::pipeline_fingerprint(),
        cfg.platform.name(),
        storekey::spec_hash(spec),
        cfg.platform.profiler_frontend().name(),
        cfg.strategy.name(),
        cfg.budget,
        cfg.patience,
        cfg.seed,
        cfg.use_evidence,
        family,
    );
    JobKey::from_text(text)
}

// bit-exact f64 round trip: the store's shared helpers, so tune
// entries and campaign entries can never drift formats
use crate::store::cache::parse_bits;
use crate::store::key::bits;

/// Bit-exact tune-result serialization (the blob payload).
pub fn serialize_tune(r: &TuneOutcome) -> String {
    format!(
        "problem_id {}\nstrategy {}\nnaive_s {}\nexpert_s {}\ntuned_s {}\nevals {}\nevals_to_best {}\nseeded {}\nschedule {}\n{TUNE_RESULT_END}",
        r.problem_id,
        r.strategy,
        bits(r.naive_s),
        bits(r.expert_s),
        bits(r.tuned_s),
        r.evals,
        r.evals_to_best,
        r.seeded,
        r.schedule.canon(),
    )
}

/// Strict inverse of [`serialize_tune`]: any missing field, unknown
/// strategy, malformed number or absent trailer is an error (= a
/// miss, recomputed).
pub fn parse_tune(text: &str) -> Result<TuneOutcome> {
    let mut lines = text.lines();
    let mut field = |name: &str| -> Result<String> {
        let line = lines.next().with_context(|| format!("tune entry truncated before {name}"))?;
        line.strip_prefix(name)
            .and_then(|rest| rest.strip_prefix(' '))
            .map(|v| v.to_string())
            .with_context(|| format!("expected {name:?} line, got {line:?}"))
    };
    let problem_id = field("problem_id")?;
    // resolve through the registry so the name is the strategy's own
    // static str; an unregistered strategy means a stale entry
    let strategy = strategy_by_name(&field("strategy")?)?.name();
    let naive_s = parse_bits(&field("naive_s")?)?;
    let expert_s = parse_bits(&field("expert_s")?)?;
    let tuned_s = parse_bits(&field("tuned_s")?)?;
    let evals: usize = field("evals")?.parse().context("bad evals count")?;
    let evals_to_best: usize =
        field("evals_to_best")?.parse().context("bad evals_to_best count")?;
    let seeded: usize = field("seeded")?.parse().context("bad seeded count")?;
    let schedule = Schedule::from_canon(&field("schedule")?)?;
    match lines.next() {
        Some(TUNE_RESULT_END) => {}
        other => bail!("missing tune trailer (got {other:?})"),
    }
    if lines.next().is_some() {
        bail!("trailing data after tune trailer");
    }
    Ok(TuneOutcome {
        problem_id,
        strategy,
        naive_s,
        expert_s,
        tuned_s,
        schedule,
        evals,
        evals_to_best,
        seeded,
    })
}

/// Tune one problem (no store involved).  Deterministic in
/// (config, problem) alone; the worker count only parallelizes the
/// pure evaluations.  Equivalent to [`tune_problem_seeded`] with no
/// donors.
pub fn tune_problem(cfg: &TuneConfig, problem: &Problem) -> TuneOutcome {
    tune_problem_seeded(cfg, problem, &[])
}

/// Tune one problem with transfer donors: tuned schedules from
/// structurally similar graphs, injected as extra seed points.
/// Deterministic in (config, problem, donors); with `use_transfer`
/// off the donors are ignored and the result is bit-identical to
/// [`tune_problem`].  Illegal or duplicate donors are dropped by
/// [`super::seed_points`] — `seeded` reports how many survived.
pub fn tune_problem_seeded(
    cfg: &TuneConfig,
    problem: &Problem,
    donors: &[Schedule],
) -> TuneOutcome {
    let spec = cfg.platform.spec();
    let donors: Vec<Schedule> = if cfg.use_transfer { donors.to_vec() } else { Vec::new() };
    let base_seeds = super::seed_points(&CostOracle::new(spec, &problem.perf_graph)).len();
    let mut oracle = CostOracle::new(spec, &problem.perf_graph)
        .with_workers(cfg.workers)
        .with_transfer_seeds(donors);
    if cfg.use_evidence {
        oracle = oracle.with_evidence(cfg.platform.profiler_frontend());
    }
    let seeded = super::seed_points(&oracle).len() - base_seeds;
    let naive_s = oracle.cost(&Schedule::naive());
    let expert_s = oracle.cost(&cfg.platform.expert_schedule());
    let mut budget = Budget::new(cfg.budget, cfg.patience);
    let mut rng = Pcg::new(
        cfg.seed ^ fnv1a(cfg.platform.name().as_bytes()),
        fnv1a(problem.id.as_bytes()),
    );
    let out = cfg.strategy.search(&oracle, &mut budget, &mut rng);
    // naive seeds every population, but a pathologically small budget
    // can stop a search before it scores anything: never report a
    // schedule worse than the untuned program
    let (schedule, tuned_s) = if out.best.cost_s <= naive_s {
        (out.best.schedule.clone(), out.best.cost_s)
    } else {
        (Schedule::naive(), naive_s)
    };
    let evals_to_best = out
        .visited
        .iter()
        .position(|s| *s == schedule)
        .map_or(out.visited.len(), |p| p + 1);
    TuneOutcome {
        problem_id: problem.id.clone(),
        strategy: cfg.strategy.name(),
        naive_s,
        expert_s,
        tuned_s,
        schedule,
        evals: out.visited.len(),
        evals_to_best,
        seeded,
    }
}

/// A legal donor schedule for `family` from the store's transfer
/// index, when one was published (by this process or any other
/// sharing the cache dir).  A malformed blob is silently no donor —
/// transfer is an accelerant, never a correctness dependency.
fn family_donor(store: &Store, cfg: &TuneConfig, family: u64) -> Option<Schedule> {
    let (text, _) = store.get_blob(&family_key(cfg, family))?;
    Schedule::from_canon(text.trim_end()).ok()
}

/// Tune a suite against an explicit store: consult before search,
/// write back after.  Problems the platform cannot run are filtered
/// exactly like campaigns filter them.
///
/// Transfer seeding (when `cfg.use_transfer`): the first tuned
/// schedule seen per [`storekey::family_fingerprint`] becomes the
/// donor for later family mates.  The in-run map is consulted first —
/// so a cold memory store and a disabled store produce bit-identical
/// outcomes — and the store's family blobs (first-wins, published as
/// they are computed) extend the same transfer across processes
/// sharing one cache dir.  Family-blob traffic is deliberately *not*
/// counted in the report's cache stats: those pin tune-entry hits and
/// misses only.
pub fn tune_suite_with(store: &Store, cfg: &TuneConfig, suite: &Suite) -> TuneReport {
    let spec = cfg.platform.spec();
    let filtered = suite.supported_on(spec);
    let mut outcomes = Vec::with_capacity(filtered.len());
    let mut cache = CacheStats::default();
    let mut families: std::collections::BTreeMap<u64, Schedule> = std::collections::BTreeMap::new();
    for problem in filtered.problems.iter() {
        let key = tune_key(cfg, problem);
        let fam = storekey::family_fingerprint(&problem.perf_graph);
        // parse inside the lookup so a corrupt payload is a miss at
        // every counting level (process counters included), exactly
        // like a corrupt TaskResult entry
        if let Some((r, bytes)) = store.get_blob_checked(&key, parse_tune) {
            cache.hits += 1;
            cache.bytes_read += bytes;
            if cfg.use_transfer {
                families.entry(fam).or_insert_with(|| r.schedule.clone());
            }
            outcomes.push(r);
            continue;
        }
        let donors: Vec<Schedule> = if cfg.use_transfer {
            families
                .get(&fam)
                .cloned()
                .or_else(|| family_donor(store, cfg, fam))
                .into_iter()
                .collect()
        } else {
            Vec::new()
        };
        let r = {
            let _s = crate::obs::span("tune.problem");
            tune_problem_seeded(cfg, problem, &donors)
        };
        if store.enabled() {
            cache.misses += 1;
            cache.bytes_written += store.put_blob(&key, &serialize_tune(&r));
        }
        if cfg.use_transfer {
            families.entry(fam).or_insert_with(|| r.schedule.clone());
            // first-wins publish for other processes on this store
            if store.enabled() && store.get_blob(&family_key(cfg, fam)).is_none() {
                store.put_blob(&family_key(cfg, fam), &r.schedule.canon());
            }
        }
        outcomes.push(r);
    }
    trace_tune_outcomes(&outcomes);
    TuneReport {
        platform: cfg.platform.name(),
        strategy: cfg.strategy.name(),
        outcomes,
        cache,
    }
}

/// Logical trace of a tune run, emitted post-hoc from the outcome
/// values (which are bit-identical warm vs cold by the store's
/// serialization contract) — so the `Snapshot::canon` digest is too.
/// Live exec events (`tune.problem` spans, oracle counters) exist only
/// where search actually ran; that asymmetry is the two-clock design
/// working as intended.
fn trace_tune_outcomes(outcomes: &[TuneOutcome]) {
    if !crate::obs::enabled() {
        return;
    }
    for o in outcomes {
        let _lane = crate::obs::lane(&format!("tune:{}", o.problem_id));
        let _span = crate::obs::logical_span(&format!("tune:{}:{}", o.strategy, o.problem_id));
        crate::obs::logical_counter("tune.evals", o.evals as u64);
        crate::obs::logical_counter("tune.evals_to_best", o.evals_to_best as u64);
        crate::obs::logical_counter("tune.seeded", o.seeded as u64);
        crate::obs::logical_gauge("tune.naive_s", o.naive_s);
        crate::obs::logical_gauge("tune.expert_s", o.expert_s);
        crate::obs::logical_gauge("tune.tuned_s", o.tuned_s);
        crate::obs::logical_instant(if o.le_expert() {
            "tune.le_expert"
        } else {
            "tune.gt_expert"
        });
    }
}

/// [`tune_suite_with`] against the process-wide store ([`store::global`]
/// — a pass-through unless the CLI configured one).
pub fn tune_suite(cfg: &TuneConfig, suite: &Suite) -> TuneReport {
    tune_suite_with(store::global(), cfg, suite)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::by_name;

    fn cfg() -> TuneConfig {
        let mut c = TuneConfig::new(by_name("cuda").unwrap());
        c.budget = 96;
        c
    }

    fn sample_outcome() -> TuneOutcome {
        let suite = Suite::sample(1);
        let mut c = cfg();
        c.budget = 48;
        tune_problem(&c, &suite.problems[0])
    }

    fn assert_bit_identical(a: &TuneOutcome, b: &TuneOutcome) {
        assert_eq!(a.problem_id, b.problem_id);
        assert_eq!(a.strategy, b.strategy);
        assert_eq!(a.naive_s.to_bits(), b.naive_s.to_bits());
        assert_eq!(a.expert_s.to_bits(), b.expert_s.to_bits());
        assert_eq!(a.tuned_s.to_bits(), b.tuned_s.to_bits());
        assert_eq!(a.schedule, b.schedule);
        assert_eq!(a.evals, b.evals);
        assert_eq!(a.evals_to_best, b.evals_to_best);
        assert_eq!(a.seeded, b.seeded);
    }

    #[test]
    fn tune_serialization_is_bit_exact_and_strict() {
        let r = sample_outcome();
        let text = serialize_tune(&r);
        assert_bit_identical(&parse_tune(&text).unwrap(), &r);
        // truncation at every interior line boundary fails
        for (i, _) in text.match_indices('\n') {
            assert!(parse_tune(&text[..i]).is_err(), "truncated at byte {i} parsed");
        }
        assert!(parse_tune(&text.replace("strategy beam", "strategy vibes")).is_err());
        assert!(parse_tune(&format!("{text}\ntrailing")).is_err());
        assert!(parse_tune("").is_err());
    }

    #[test]
    fn tune_key_covers_every_knob() {
        let suite = Suite::sample(1);
        let problem = &suite.problems[0];
        let base = tune_key(&cfg(), problem);
        assert!(base.text.starts_with(TUNE_MAGIC));
        assert!(base.text.contains(&format!("schema {STORE_SCHEMA}")));
        let mutations: Vec<Box<dyn Fn(&mut TuneConfig)>> = vec![
            Box::new(|c| c.strategy = strategy_by_name("evolve").unwrap()),
            Box::new(|c| c.budget += 1),
            Box::new(|c| c.patience += 1),
            Box::new(|c| c.seed ^= 1),
            Box::new(|c| c.use_evidence = false),
            Box::new(|c| c.use_transfer = false),
            Box::new(|c| c.platform = by_name("rocm").unwrap()),
        ];
        for (i, m) in mutations.iter().enumerate() {
            let mut c = cfg();
            m(&mut c);
            assert_ne!(tune_key(&c, problem).hex(), base.hex(), "mutation {i} did not flip the key");
        }
        // worker count deliberately does NOT flip the key
        let mut c = cfg();
        c.workers = 16;
        assert_eq!(tune_key(&c, problem).hex(), base.hex());
        // a different problem flips it
        let other = &Suite::sample(2).problems[1];
        assert_ne!(tune_key(&cfg(), other).hex(), base.hex());
    }

    #[test]
    fn tune_problem_never_worse_than_naive_and_reaches_expert_sometimes() {
        let suite = Suite::sample(2); // 6 problems
        let mut c = cfg();
        c.budget = 320; // enough beam rounds to stack 3+ lever moves
        let mut beats_expert = 0;
        for p in suite.problems.iter() {
            let r = tune_problem(&c, p);
            assert!(r.le_naive(), "{}: tuned {} > naive {}", p.id, r.tuned_s, r.naive_s);
            assert!(r.evals > 0 && r.evals <= c.budget);
            crate::sched::legal::check(&r.schedule, c.platform.spec()).unwrap();
            if r.le_expert() {
                beats_expert += 1;
            }
        }
        assert!(beats_expert > 0, "beam at budget 320 should match the expert somewhere");
    }

    #[test]
    fn tune_suite_caches_and_report_summarizes() {
        let suite = Suite::sample(1); // 3 problems
        let store = Store::memory();
        let c = cfg();
        let cold = tune_suite_with(&store, &c, &suite);
        assert_eq!(cold.cache.misses, 3);
        assert_eq!(cold.cache.hits, 0);
        let warm = tune_suite_with(&store, &c, &suite);
        assert_eq!(warm.cache.hits, 3);
        assert_eq!(warm.cache.misses, 0);
        for (a, b) in cold.outcomes.iter().zip(&warm.outcomes) {
            assert_bit_identical(a, b);
        }
        let s = warm.summary();
        assert!(s.contains("autotuned<=naive: 3/3 (100.0%)"), "{s}");
        assert!(s.contains("autotuned<=expert:"), "{s}");
        // disabled store: zero counters, same outcomes — donor lookup
        // must stay store-independent within one run
        let off = tune_suite_with(&Store::disabled(), &c, &suite);
        assert_eq!(off.cache, CacheStats::default());
        for (a, b) in cold.outcomes.iter().zip(&off.outcomes) {
            assert_bit_identical(a, b);
        }
    }

    #[test]
    fn transfer_donor_never_worsens_and_is_counted() {
        let suite = Suite::sample(1);
        let problem = &suite.problems[0];
        let c = cfg();
        let plain = tune_problem(&c, problem);
        assert_eq!(plain.seeded, 0);
        assert!(plain.evals_to_best >= 1 && plain.evals_to_best <= plain.evals);
        // donor = the problem's own tuned schedule: it sits in the seed
        // population, so the seeded search can never end above it
        let seeded = tune_problem_seeded(&c, problem, &[plain.schedule.clone()]);
        assert!(
            seeded.tuned_s <= plain.tuned_s,
            "seeded {} worse than donor {}",
            seeded.tuned_s,
            plain.tuned_s
        );
        assert!(seeded.le_naive());
        assert!(seeded.evals_to_best >= 1 && seeded.evals_to_best <= seeded.evals);
        // an illegal-or-duplicate-free donor counts once; the naive
        // duplicate folds away
        let dup = tune_problem_seeded(
            &c,
            problem,
            &[Schedule::naive(), plain.schedule.clone(), plain.schedule.clone()],
        );
        assert!(dup.seeded <= 1, "duplicate donors must fold: {}", dup.seeded);
        // transfer off: donors ignored, bit-identical to the plain run
        let mut off = cfg();
        off.use_transfer = false;
        let ignored = tune_problem_seeded(&off, problem, &[plain.schedule.clone()]);
        assert_eq!(ignored.seeded, 0);
        assert_eq!(ignored.tuned_s.to_bits(), tune_problem(&off, problem).tuned_s.to_bits());
        // determinism: same donors, same outcome, any worker count
        let mut wide = cfg();
        wide.workers = 8;
        assert_bit_identical(&seeded, &tune_problem_seeded(&wide, problem, &[plain.schedule.clone()]));
    }

    #[test]
    fn family_blobs_transfer_across_store_sharing_runs() {
        let sample = Suite::sample(1);
        let problem = &sample.problems[0];
        let one = Suite { problems: std::sync::Arc::new(vec![problem.clone()]) };
        let c = cfg();
        let fam = storekey::family_fingerprint(&problem.perf_graph);
        let store = Store::memory();
        let first = tune_suite_with(&store, &c, &one);
        // the run published a donor blob for the problem's family
        let donor = super::family_donor(&store, &c, fam).expect("family blob published");
        assert_eq!(donor, first.outcomes[0].schedule);
        // a second store holding only the family blob (no tune entry):
        // the suite driver must pick the donor up from the blob index,
        // agreeing bit-for-bit with the explicit-donor path
        let store2 = Store::memory();
        store2.put_blob(&family_key(&c, fam), &donor.canon());
        let via_blob = tune_suite_with(&store2, &c, &one);
        assert_eq!(via_blob.cache.misses, 1);
        assert_bit_identical(&via_blob.outcomes[0], &tune_problem_seeded(&c, problem, &[donor.clone()]));
        // family keys cover the knobs: a different budget looks up a
        // different family blob
        let mut other = cfg();
        other.budget += 1;
        assert!(super::family_donor(&store, &other, fam).is_none());
        assert_ne!(family_key(&c, fam).hex(), family_key(&other, fam).hex());
    }
}
