//! The evaluation budget / early-stop controller every strategy runs
//! under.  A budget bounds total oracle evaluations (`max_evals`) and
//! stops a search whose frontier has gone stale (`patience` rounds with
//! no strict improvement) — the knob `kforge tune --budget` exposes and
//! the tune key fingerprints.

/// Evaluation budget + patience-based early stop.
#[derive(Debug, Clone)]
pub struct Budget {
    max_evals: usize,
    patience: usize,
    used: usize,
    stale_rounds: usize,
    best_seen: f64,
    stopped_early: bool,
}

impl Budget {
    /// `max_evals` total candidate evaluations; early-stop after
    /// `patience` consecutive rounds without a strictly better cost.
    pub fn new(max_evals: usize, patience: usize) -> Budget {
        Budget {
            max_evals,
            patience: patience.max(1),
            used: 0,
            stale_rounds: 0,
            best_seen: f64::INFINITY,
            stopped_early: false,
        }
    }

    /// Evaluations still available.
    pub fn remaining(&self) -> usize {
        self.max_evals.saturating_sub(self.used)
    }

    /// Evaluations consumed so far.
    pub fn used(&self) -> usize {
        self.used
    }

    /// Claim up to `n` evaluations; returns the granted count (0 when
    /// exhausted).  Strategies must truncate their batch to the grant.
    pub fn take(&mut self, n: usize) -> usize {
        let granted = n.min(self.remaining());
        self.used += granted;
        granted
    }

    /// Record a round's best cost.  Returns `false` when the search
    /// should stop early (the frontier has been stale for `patience`
    /// rounds).
    pub fn observe(&mut self, round_best: f64) -> bool {
        if round_best < self.best_seen {
            self.best_seen = round_best;
            self.stale_rounds = 0;
        } else {
            self.stale_rounds += 1;
        }
        if self.stale_rounds >= self.patience {
            self.stopped_early = true;
        }
        !self.stopped_early
    }

    /// Should the strategy start another round?
    pub fn should_continue(&self) -> bool {
        self.remaining() > 0 && !self.stopped_early
    }

    /// Did the patience rule fire (as opposed to plain exhaustion)?
    pub fn stopped_early(&self) -> bool {
        self.stopped_early
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_never_overdraws() {
        let mut b = Budget::new(10, 3);
        assert_eq!(b.take(6), 6);
        assert_eq!(b.take(6), 4);
        assert_eq!(b.take(6), 0);
        assert_eq!(b.used(), 10);
        assert_eq!(b.remaining(), 0);
        assert!(!b.should_continue());
        assert!(!b.stopped_early());
    }

    #[test]
    fn patience_stops_stale_searches() {
        let mut b = Budget::new(1000, 2);
        assert!(b.observe(5.0)); // improvement (from infinity)
        assert!(b.observe(4.0)); // improvement
        assert!(b.observe(4.0)); // stale 1
        assert!(!b.observe(4.0)); // stale 2 -> stop
        assert!(b.stopped_early());
        assert!(!b.should_continue());
    }

    #[test]
    fn improvement_resets_patience() {
        let mut b = Budget::new(1000, 2);
        assert!(b.observe(5.0));
        assert!(b.observe(5.0)); // stale 1
        assert!(b.observe(4.0)); // improvement resets
        assert!(b.observe(4.0)); // stale 1 again
        assert!(!b.observe(4.0)); // stale 2 -> stop
    }

    #[test]
    fn zero_patience_is_clamped_to_one() {
        let mut b = Budget::new(10, 0);
        assert!(b.observe(1.0)); // improvement
        assert!(!b.observe(1.0)); // first stale round stops
    }
}
