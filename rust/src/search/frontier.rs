//! The `search_frontier_<platform>` conformance artifact: search
//! behavior, golden-pinned per registered platform.
//!
//! One table per registered strategy over a small curated slice, plus
//! the acceptance summary lines (`autotuned<=naive`, `autotuned<=expert`,
//! geomean speedup).  Rendering goes through [`super::tune_suite`], so
//! under the CLI a `--cache-dir` warms the tune cache and a warm render
//! is byte-identical to a cold one — the same contract every other
//! golden artifact carries.  Registering a new platform (or strategy)
//! changes the artifact set and fails conformance until the new
//! frontier is reviewed and blessed, by design.

use super::tune::{tune_suite, TuneConfig, TuneReport};
use super::strategies;
use crate::harness::{render, Artifact, Scale};
use crate::platform::PlatformRef;
use crate::workloads::Suite;

/// Render one tune report as the fixed-format table plus its summary
/// lines — the single source both the `kforge tune` CLI and the
/// golden-pinned frontier artifacts print, so the two can never
/// diverge column-by-column.
pub fn render_report(title: &str, report: &TuneReport) -> String {
    let rows: Vec<Vec<String>> = report
        .outcomes
        .iter()
        .map(|o| {
            vec![
                o.problem_id.clone(),
                format!("{:.4}", o.naive_s * 1e3),
                format!("{:.4}", o.expert_s * 1e3),
                format!("{:.4}", o.tuned_s * 1e3),
                format!("{:.2}x", o.speedup_vs_naive()),
                if o.le_expert() { "yes" } else { "no" }.to_string(),
                o.evals.to_string(),
                o.schedule.canon(),
            ]
        })
        .collect();
    let table = render::table(
        title,
        &["problem", "naive ms", "expert ms", "tuned ms", "vs naive", "<=expert", "evals", "schedule"],
        &rows,
    );
    format!("{table}{}", report.summary())
}

/// Per-problem search budget for the golden-pinned render: small
/// enough to keep `kforge conformance` fast, large enough that beam
/// stacks several lever moves on the curated problems.
pub const FRONTIER_BUDGET: usize = 96;

/// The frontier artifact for one platform.
pub fn artifact(platform: &PlatformRef, scale: Scale) -> Artifact {
    Artifact::new(
        format!("search_frontier_{}", platform.name()),
        render_frontier(platform, scale),
    )
}

/// Render the frontier text for one platform at `scale`.
pub fn render_frontier(platform: &PlatformRef, scale: Scale) -> String {
    // the frontier golden is a behavioral pin, not a benchmark: cap
    // the slice so even a Full-scale bless stays minutes, not hours
    let per_level = match scale {
        Scale::Full => 4,
        Scale::Quick(n) => n.min(4),
    };
    let suite = Suite::sample(per_level);
    let mut out = format!(
        "== Search frontier: {} ({} problems/level, budget {}) ==\n",
        platform.name(),
        per_level,
        FRONTIER_BUDGET
    );
    for strategy in strategies() {
        let mut cfg = TuneConfig::new(platform.clone());
        cfg.strategy = strategy.clone();
        cfg.budget = FRONTIER_BUDGET;
        let report = tune_suite(&cfg, &suite);
        out.push_str(&render_report(
            &format!("strategy: {} — {}", strategy.name(), strategy.describe()),
            &report,
        ));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::by_name;

    #[test]
    fn frontier_artifact_is_deterministic_and_pins_the_acceptance_lines() {
        let platform = by_name("cuda").unwrap();
        let a = artifact(&platform, Scale::Quick(2));
        assert_eq!(a.name, "search_frontier_cuda");
        // the curated acceptance fraction: tuned <= naive on 100%
        assert!(a.text.contains("autotuned<=naive: 6/6 (100.0%)"), "{}", a.text);
        assert!(a.text.contains("autotuned<=expert:"), "{}", a.text);
        // one section per registered strategy
        for s in crate::search::strategies() {
            assert!(a.text.contains(&format!("strategy: {}", s.name())), "{}", a.text);
        }
        // byte determinism (the golden differ's precondition)
        let b = artifact(&platform, Scale::Quick(2));
        assert_eq!(a.text.as_bytes(), b.text.as_bytes());
    }

    #[test]
    fn frontier_respects_the_platform_suite_filter() {
        // metal's artifact must only carry problems metal supports
        let metal = by_name("metal").unwrap();
        let text = render_frontier(&metal, Scale::Quick(2));
        assert!(!text.contains("conv3d_transpose"), "{text}");
        assert!(text.contains("autotuned<=naive"), "{text}");
    }
}
