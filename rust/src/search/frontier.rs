//! The `search_frontier_<platform>` conformance artifact: search
//! behavior, golden-pinned per registered platform.
//!
//! One table per registered strategy over a small curated slice, plus
//! the acceptance summary lines (`autotuned<=naive`, `autotuned<=expert`,
//! geomean speedup).  Rendering goes through [`super::tune_suite`], so
//! under the CLI a `--cache-dir` warms the tune cache and a warm render
//! is byte-identical to a cold one — the same contract every other
//! golden artifact carries.  Registering a new platform (or strategy)
//! changes the artifact set and fails conformance until the new
//! frontier is reviewed and blessed, by design.

use super::tune::{tune_problem, tune_problem_seeded, tune_suite, TuneConfig, TuneReport};
use super::strategies;
use crate::harness::{render, Artifact, Scale};
use crate::platform::PlatformRef;
use crate::workloads::{Problem, Suite};

/// Render one tune report as the fixed-format table plus its summary
/// lines — the single source both the `kforge tune` CLI and the
/// golden-pinned frontier artifacts print, so the two can never
/// diverge column-by-column.
pub fn render_report(title: &str, report: &TuneReport) -> String {
    let rows: Vec<Vec<String>> = report
        .outcomes
        .iter()
        .map(|o| {
            vec![
                o.problem_id.clone(),
                format!("{:.4}", o.naive_s * 1e3),
                format!("{:.4}", o.expert_s * 1e3),
                format!("{:.4}", o.tuned_s * 1e3),
                format!("{:.2}x", o.speedup_vs_naive()),
                if o.le_expert() { "yes" } else { "no" }.to_string(),
                o.evals.to_string(),
                o.schedule.canon(),
            ]
        })
        .collect();
    let table = render::table(
        title,
        &["problem", "naive ms", "expert ms", "tuned ms", "vs naive", "<=expert", "evals", "schedule"],
        &rows,
    );
    format!("{table}{}", report.summary())
}

/// Per-problem search budget for the golden-pinned render: small
/// enough to keep `kforge conformance` fast, large enough that beam
/// stacks several lever moves on the curated problems.
pub const FRONTIER_BUDGET: usize = 96;

/// The frontier artifact for one platform.
pub fn artifact(platform: &PlatformRef, scale: Scale) -> Artifact {
    Artifact::new(
        format!("search_frontier_{}", platform.name()),
        render_frontier(platform, scale),
    )
}

/// Render the frontier text for one platform at `scale`.
pub fn render_frontier(platform: &PlatformRef, scale: Scale) -> String {
    // the frontier golden is a behavioral pin, not a benchmark: cap
    // the slice so even a Full-scale bless stays minutes, not hours
    let per_level = match scale {
        Scale::Full => 4,
        Scale::Quick(n) => n.min(4),
    };
    let suite = Suite::sample(per_level);
    let mut out = format!(
        "== Search frontier: {} ({} problems/level, budget {}) ==\n",
        platform.name(),
        per_level,
        FRONTIER_BUDGET
    );
    for strategy in strategies() {
        let mut cfg = TuneConfig::new(platform.clone());
        cfg.strategy = strategy.clone();
        cfg.budget = FRONTIER_BUDGET;
        let report = tune_suite(&cfg, &suite);
        out.push_str(&render_report(
            &format!("strategy: {} — {}", strategy.name(), strategy.describe()),
            &report,
        ));
        out.push('\n');
    }
    out.push_str(&render_transfer(platform));
    out
}

/// The cross-problem transfer measurement: for the first schedule
/// family (see [`crate::store::key::family_fingerprint`]) with at
/// least two platform-supported members, tune the first member cold
/// and re-tune each mate twice — once cold, once seeded with the
/// donor's tuned schedule — reporting evaluations-to-frontier both
/// ways.  Store-free and pure, so the section is byte-deterministic
/// like the tables above it; the `<=naive` column pins that seeding
/// never worsens the tuned frontier.
fn render_transfer(platform: &PlatformRef) -> String {
    use crate::store::key::family_fingerprint;
    let spec = platform.spec();
    let full = Suite::full();
    let mut seen: std::collections::BTreeMap<u64, Vec<&Problem>> = std::collections::BTreeMap::new();
    // suite order decides both the chosen family (first to reach two
    // members) and the donor (its first member) — fully deterministic
    let mut chosen: Option<u64> = None;
    for p in full.problems.iter().filter(|p| p.supported_on(spec)) {
        let fam = family_fingerprint(&p.perf_graph);
        let entry = seen.entry(fam).or_default();
        entry.push(p);
        if chosen.is_none() && entry.len() == 2 {
            chosen = Some(fam);
        }
    }
    let Some(fam) = chosen else {
        return "transfer: no schedule-family mates on this platform\n".to_string();
    };
    let members = &seen[&fam];
    let members = &members[..members.len().min(3)];
    let mut cfg = TuneConfig::new(platform.clone());
    cfg.budget = FRONTIER_BUDGET;
    let donor = tune_problem(&cfg, members[0]);
    let mut rows = Vec::new();
    let mut saved_total: i64 = 0;
    for p in &members[1..] {
        let cold = tune_problem(&cfg, p);
        let seeded = tune_problem_seeded(&cfg, p, std::slice::from_ref(&donor.schedule));
        let saved = cold.evals_to_best as i64 - seeded.evals_to_best as i64;
        saved_total += saved;
        rows.push(vec![
            p.id.clone(),
            cold.evals_to_best.to_string(),
            seeded.evals_to_best.to_string(),
            format!("{saved:+}"),
            format!("{:.4}", cold.tuned_s * 1e3),
            format!("{:.4}", seeded.tuned_s * 1e3),
            if seeded.tuned_s <= cold.naive_s { "yes" } else { "no" }.to_string(),
        ]);
    }
    let table = render::table(
        &format!("transfer: family {fam:016x}, donor {}", members[0].id),
        &["problem", "cold evals-to-frontier", "seeded", "saved", "cold tuned ms", "seeded tuned ms", "<=naive"],
        &rows,
    );
    format!(
        "{table}transfer evaluations-to-frontier saved: {saved_total:+} across {} mate(s)\n",
        rows.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::by_name;

    #[test]
    fn frontier_artifact_is_deterministic_and_pins_the_acceptance_lines() {
        let platform = by_name("cuda").unwrap();
        let a = artifact(&platform, Scale::Quick(2));
        assert_eq!(a.name, "search_frontier_cuda");
        // the curated acceptance fraction: tuned <= naive on 100%
        assert!(a.text.contains("autotuned<=naive: 6/6 (100.0%)"), "{}", a.text);
        assert!(a.text.contains("autotuned<=expert:"), "{}", a.text);
        // one section per registered strategy
        for s in crate::search::strategies() {
            assert!(a.text.contains(&format!("strategy: {}", s.name())), "{}", a.text);
        }
        // the transfer measurement section rides along
        assert!(a.text.contains("transfer"), "{}", a.text);
        assert!(a.text.contains("evaluations-to-frontier saved:"), "{}", a.text);
        // byte determinism (the golden differ's precondition)
        let b = artifact(&platform, Scale::Quick(2));
        assert_eq!(a.text.as_bytes(), b.text.as_bytes());
    }

    #[test]
    fn transfer_section_pins_le_naive_on_every_mate() {
        let text = render_transfer(&by_name("cuda").unwrap());
        assert!(text.contains("transfer: family"), "{text}");
        // every mate row's <=naive verdict (last column) must be yes:
        // transfer seeding is never allowed to worsen the frontier
        let mut mates = 0;
        for line in text.lines() {
            if line.starts_with("l1_") || line.starts_with("l2_") || line.starts_with("l3_") {
                mates += 1;
                assert!(line.trim_end().ends_with("yes"), "{line}");
            }
        }
        assert!(mates >= 1, "no mate rows rendered:\n{text}");
    }

    #[test]
    fn frontier_respects_the_platform_suite_filter() {
        // metal's artifact must only carry problems metal supports
        let metal = by_name("metal").unwrap();
        let text = render_frontier(&metal, Scale::Quick(2));
        assert!(!text.contains("conv3d_transpose"), "{text}");
        assert!(text.contains("autotuned<=naive"), "{text}");
    }
}
