//! Beam search over single-lever `Schedule` neighborhoods.
//!
//! The deterministic workhorse strategy: keep the `width` best points,
//! expand every legal single-lever move of each
//! ([`super::neighbors::neighbors`]), score the unseen expansions
//! through the oracle's worker fan-out, keep the best `width` of the
//! merged frontier, repeat until the budget or patience runs out.  The
//! lever neighborhoods are exactly the moves the agents'
//! `Lever::improve` steps take, so beam search is the exhaustive
//! counterpart of the persona optimization pass — the paper-grade
//! "best-effort search" arm.

use super::neighbors;
use super::{score_batch, seed_points, sort_frontier, Budget, CostOracle, SearchOutcome, SearchStrategy};
use crate::util::rng::Pcg;
use std::collections::BTreeSet;

/// Beam search strategy.  `width` is the frontier size kept per round.
#[derive(Debug, Clone)]
pub struct BeamStrategy {
    pub width: usize,
}

impl Default for BeamStrategy {
    fn default() -> BeamStrategy {
        BeamStrategy { width: 4 }
    }
}

impl SearchStrategy for BeamStrategy {
    fn name(&self) -> &'static str {
        "beam"
    }

    fn describe(&self) -> &'static str {
        "beam search over legality-filtered single-lever schedule neighborhoods"
    }

    fn search(&self, oracle: &CostOracle<'_>, budget: &mut Budget, _rng: &mut Pcg) -> SearchOutcome {
        let spec = oracle.spec();
        let width = self.width.max(1);
        let mut visited = Vec::new();
        let seeds = seed_points(oracle);
        // membership-only set (order never read), so determinism holds
        let mut seen: BTreeSet<String> = seeds.iter().map(|s| s.canon()).collect();
        let mut beam = score_batch(oracle, budget, seeds, &mut visited);
        sort_frontier(&mut beam);
        beam.truncate(width);
        if let Some(head) = beam.first() {
            budget.observe(head.cost_s);
        }
        while budget.should_continue() && !beam.is_empty() {
            let mut expansions = Vec::new();
            for point in &beam {
                for cand in neighbors::neighbors(&point.schedule, spec) {
                    if seen.insert(cand.canon()) {
                        expansions.push(cand);
                    }
                }
            }
            if expansions.is_empty() {
                break; // neighborhood exhausted around the frontier
            }
            let scored = score_batch(oracle, budget, expansions, &mut visited);
            if scored.is_empty() {
                break; // budget exhausted mid-round
            }
            let mut merged = beam.clone();
            merged.extend(scored);
            sort_frontier(&mut merged);
            merged.truncate(width);
            let round_best = merged[0].cost_s;
            beam = merged;
            if !budget.observe(round_best) {
                break;
            }
        }
        oracle.rerank(&mut beam);
        let best = beam.first().cloned().unwrap_or_else(|| super::Scored {
            schedule: crate::sched::Schedule::naive(),
            cost_s: f64::INFINITY,
        });
        SearchOutcome { best, frontier: beam, visited }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::cuda;
    use crate::sched::Schedule;
    use crate::workloads::Suite;

    #[test]
    fn beam_improves_on_naive_and_is_deterministic() {
        let suite = Suite::sample(1);
        let problem = &suite.problems[0];
        let spec = cuda::h100();
        let oracle = CostOracle::new(&spec, &problem.perf_graph);
        let naive = oracle.cost(&Schedule::naive());
        let run = |workers: usize| {
            let oracle = CostOracle::new(&spec, &problem.perf_graph).with_workers(workers);
            let mut budget = Budget::new(160, 3);
            let mut rng = Pcg::seed(1);
            BeamStrategy::default().search(&oracle, &mut budget, &mut rng)
        };
        let a = run(1);
        assert!(a.best.cost_s <= naive, "beam {} worse than naive {naive}", a.best.cost_s);
        assert!(!a.visited.is_empty());
        assert_eq!(a.best.schedule, a.frontier[0].schedule);
        // worker-count invariance, down to the visit order and bits
        let b = run(8);
        assert_eq!(a.visited, b.visited);
        assert_eq!(a.best.schedule, b.best.schedule);
        assert_eq!(a.best.cost_s.to_bits(), b.best.cost_s.to_bits());
    }

    #[test]
    fn beam_respects_a_tiny_budget() {
        let suite = Suite::sample(1);
        let problem = &suite.problems[0];
        let spec = cuda::h100();
        let oracle = CostOracle::new(&spec, &problem.perf_graph);
        let mut budget = Budget::new(3, 2);
        let mut rng = Pcg::seed(1);
        let out = BeamStrategy::default().search(&oracle, &mut budget, &mut rng);
        assert!(out.visited.len() <= 3);
        assert!(out.best.cost_s.is_finite());
    }
}
