//! The schedule autotuner: deterministic, seeded, population-based
//! search over the [`crate::sched::Schedule`] space.
//!
//! KForge's optimization pass is agent-driven — personas move one
//! `Lever` per iteration under analysis-agent advice — so the system
//! never explored the schedule space it can already cost
//! ([`crate::perfsim`]) and legality-check ([`crate::sched::legal`]).
//! KernelBench-style evaluations argue every synthesis claim needs a
//! *tuned-baseline* arm to be credible; this subsystem is that arm:
//! the strongest non-agent comparator the repo can field, consuming
//! all three open plugin APIs at once:
//!
//! - the **platform registry** — every strategy is platform-generic:
//!   candidates come only from legality-filtered generators
//!   ([`neighbors`]) parameterized by the `PlatformSpec`, with zero
//!   per-platform match arms anywhere in this module tree;
//! - the **profiler Evidence IR** — the cost oracle ([`oracle`]) can
//!   re-rank near-tied frontiers from the platform frontend's
//!   interpreted evidence (launch pressure, occupancy), never from the
//!   capture format;
//! - the **result store** — tune results are cached under their own
//!   `kforge-tunekey` key kind ([`tune`]), so `kforge tune`, the
//!   `--baseline autotuned` campaign arm and the `search_frontier_*`
//!   conformance artifacts never search the same (platform, problem)
//!   twice.
//!
//! Strategies are an open plugin surface exactly like platforms and
//! profiler frontends: implement [`SearchStrategy`], register it in
//! [`strategies`], done — the `kforge tune` CLI, the property tests and
//! the golden-pinned frontier artifacts pick it up from the registry
//! (see ROADMAP.md's "Adding a search strategy" guide).
//!
//! Determinism contract (CI- and property-test-enforced): a strategy
//! draws randomness only from the `Pcg` it is handed, scores candidates
//! only through the pure [`CostOracle`] (fanned across the worker pool
//! — worker count never changes values), and emits only candidates that
//! pass `legal::check` on the target spec.  A full `kforge tune` run is
//! therefore bit-identical across worker counts and warm vs cold store.

pub mod beam;
pub mod budget;
pub mod evolve;
pub mod frontier;
pub mod neighbors;
pub mod oracle;
pub mod tune;

pub use beam::BeamStrategy;
pub use budget::Budget;
pub use evolve::EvolveStrategy;
pub use oracle::{price, reprice, CostOracle, PricedPlan};
pub use tune::{
    tune_problem, tune_problem_seeded, tune_suite, tune_suite_with, TuneConfig, TuneOutcome,
    TuneReport,
};

use crate::sched::{legal, Schedule};
use crate::util::rng::Pcg;
use anyhow::{bail, Result};
use std::fmt;
use std::sync::{Arc, OnceLock};

/// Shared handle to a registered search strategy.
pub type StrategyRef = Arc<dyn SearchStrategy>;

/// One scored point on a search frontier.
#[derive(Debug, Clone)]
pub struct Scored {
    pub schedule: Schedule,
    /// Noise-free simulated seconds ([`crate::perfsim::ideal_time`]).
    pub cost_s: f64,
}

/// What a strategy hands back for one (platform, problem) search.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// The winning point (also `frontier[0]`).
    pub best: Scored,
    /// The final frontier, best first (evidence re-rank applied when
    /// the oracle carries a profiler frontend).
    pub frontier: Vec<Scored>,
    /// Every candidate the strategy evaluated, in evaluation order —
    /// the legality property tests sweep this, so strategies must not
    /// evaluate anything they do not record here.
    pub visited: Vec<Schedule>,
}

/// A schedule-search strategy — the third open plugin surface, shaped
/// like [`crate::platform::Platform`] and
/// [`crate::profiler::ProfilerFrontend`].
pub trait SearchStrategy: fmt::Debug + Send + Sync {
    /// Stable lowercase strategy id ("beam", "evolve").
    fn name(&self) -> &'static str;

    /// One-line description for `kforge tune` listings.
    fn describe(&self) -> &'static str;

    /// Run the search.  The oracle carries the target spec and graph;
    /// all randomness must come from `rng`, all scoring from the
    /// oracle, and every evaluated candidate must be legal on the
    /// oracle's spec and recorded in [`SearchOutcome::visited`].
    fn search(&self, oracle: &CostOracle<'_>, budget: &mut Budget, rng: &mut Pcg) -> SearchOutcome;
}

/// The registered strategies, in a stable order.  Adding a strategy is
/// one line here plus its module — the CLI, the frontier artifacts and
/// the property tests all iterate this registry.
pub fn strategies() -> &'static [StrategyRef] {
    static STRATEGIES: OnceLock<Vec<StrategyRef>> = OnceLock::new();
    STRATEGIES.get_or_init(|| {
        vec![
            Arc::new(BeamStrategy::default()) as StrategyRef,
            Arc::new(EvolveStrategy::default()) as StrategyRef,
        ]
    })
}

/// Look up a strategy by name.  Unknown names are an error listing
/// everything registered (never a panic).
pub fn strategy_by_name(name: &str) -> Result<StrategyRef> {
    for s in strategies() {
        if s.name() == name {
            return Ok(s.clone());
        }
    }
    bail!(
        "unknown search strategy {name:?}; registered strategies: {}",
        strategies().iter().map(|s| s.name()).collect::<Vec<_>>().join(", ")
    )
}

/// The starting points every strategy seeds its population with: the
/// naive schedule (so the search result can never be worse than an
/// untuned program), the platform's stock-kernel schedule, and any
/// transfer seeds the oracle carries (tuned schedules from
/// structurally similar graphs — see
/// [`CostOracle::with_transfer_seeds`]).  Transfer seeds are
/// legality-filtered and deduplicated, so they can only *add*
/// candidates; the naive guarantee is untouched.  The expert point is
/// deliberately *not* seeded — whether search reaches it is exactly
/// what the frontier artifacts report.
pub(crate) fn seed_points(oracle: &CostOracle<'_>) -> Vec<Schedule> {
    let spec = oracle.spec();
    let mut out = vec![Schedule::naive()];
    let stock = crate::baseline::eager::stock_schedule(spec);
    if legal::check(&stock, spec).is_ok() && !out.contains(&stock) {
        out.push(stock);
    }
    for s in oracle.transfer_seeds() {
        if legal::check(s, spec).is_ok() && !out.contains(s) {
            out.push(s.clone());
        }
    }
    out
}

/// Sort a frontier best-first, fully deterministically: by cost bit
/// pattern, ties broken by the canonical schedule rendering.  Equal
/// schedules (now adjacent) are deduplicated.
pub(crate) fn sort_frontier(xs: &mut Vec<Scored>) {
    xs.sort_by(|a, b| {
        a.cost_s
            .total_cmp(&b.cost_s)
            .then_with(|| a.schedule.canon().cmp(&b.schedule.canon()))
    });
    xs.dedup_by(|a, b| a.schedule == b.schedule);
    crate::obs::counter("search.frontier.points", xs.len() as u64);
}

/// Evaluate a candidate batch against the budget: charges up to
/// `cands.len()` evaluations, scores the granted prefix through the
/// oracle's worker fan-out, and records it in `visited`.
pub(crate) fn score_batch(
    oracle: &CostOracle<'_>,
    budget: &mut Budget,
    mut cands: Vec<Schedule>,
    visited: &mut Vec<Schedule>,
) -> Vec<Scored> {
    let granted = budget.take(cands.len());
    cands.truncate(granted);
    if cands.is_empty() {
        return Vec::new();
    }
    crate::obs::counter("search.evaluated", granted as u64);
    let costs = oracle.cost_many(&cands);
    visited.extend(cands.iter().cloned());
    cands
        .into_iter()
        .zip(costs)
        .map(|(schedule, cost_s)| Scored { schedule, cost_s })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_beam_and_evolve_with_distinct_names() {
        let names: Vec<&str> = strategies().iter().map(|s| s.name()).collect();
        assert!(names.contains(&"beam"));
        assert!(names.contains(&"evolve"));
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "duplicate strategy names");
        for s in strategies() {
            assert!(!s.describe().is_empty());
        }
    }

    #[test]
    fn unknown_strategy_is_error_listing_the_registry() {
        let err = strategy_by_name("annealing").unwrap_err().to_string();
        assert!(err.contains("annealing"), "{err}");
        assert!(err.contains("beam") && err.contains("evolve"), "{err}");
        assert_eq!(strategy_by_name("beam").unwrap().name(), "beam");
    }

    #[test]
    fn seed_points_are_legal_everywhere_and_include_naive() {
        let suite = crate::workloads::Suite::sample(1);
        let graph = &suite.problems[0].perf_graph;
        for platform in crate::platform::registry().platforms() {
            let spec = platform.spec();
            let oracle = CostOracle::new(spec, graph);
            let seeds = seed_points(&oracle);
            assert_eq!(seeds[0], Schedule::naive());
            assert!(seeds.len() >= 2, "{}: stock seed missing", platform.name());
            for s in &seeds {
                legal::check(s, spec)
                    .unwrap_or_else(|e| panic!("{}: seed illegal: {e}", platform.name()));
            }
        }
    }

    #[test]
    fn transfer_seeds_extend_but_never_displace_or_duplicate() {
        let suite = crate::workloads::Suite::sample(1);
        let graph = &suite.problems[0].perf_graph;
        let spec = crate::platform::cuda::h100();
        let base = seed_points(&CostOracle::new(&spec, graph));
        // a distinct legal donor is appended after the built-in seeds
        let mut donor = Schedule::naive();
        donor.fast_math = true;
        legal::check(&donor, &spec).expect("test donor must be legal");
        assert!(!base.contains(&donor), "donor must not collide with built-ins");
        let oracle = CostOracle::new(&spec, graph)
            .with_transfer_seeds(vec![Schedule::naive(), donor.clone(), donor.clone()]);
        let seeded = seed_points(&oracle);
        assert_eq!(seeded[0], Schedule::naive(), "naive stays first");
        assert_eq!(seeded.len(), base.len() + 1, "dup donors fold away");
        assert_eq!(seeded.last(), Some(&donor));
        for s in &seeded {
            legal::check(s, &spec).expect("every seed stays legal");
        }
    }

    #[test]
    fn sort_frontier_is_deterministic_and_dedups() {
        let a = Schedule::naive();
        let mut b = Schedule::naive();
        b.fast_math = true;
        let mut xs = vec![
            Scored { schedule: b.clone(), cost_s: 2.0 },
            Scored { schedule: a.clone(), cost_s: 1.0 },
            Scored { schedule: a.clone(), cost_s: 1.0 },
        ];
        sort_frontier(&mut xs);
        assert_eq!(xs.len(), 2);
        assert_eq!(xs[0].schedule, a);
        assert_eq!(xs[1].schedule, b);
        // equal costs order by canonical rendering, not insertion order
        let mut ys = vec![
            Scored { schedule: b.clone(), cost_s: 1.0 },
            Scored { schedule: a.clone(), cost_s: 1.0 },
        ];
        sort_frontier(&mut ys);
        let keys: Vec<String> = ys.iter().map(|s| s.schedule.canon()).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }
}
