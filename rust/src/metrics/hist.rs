//! Log-bucketed latency histogram for the serve observability surface.
//!
//! Fixed bucket bounds (doubling from 0.25 ms), `le`-style cumulative
//! rendering — one greppable line per snapshot, plus the bucket array
//! the `kforge-serve-v1` JSON summary embeds.  Recording is exact
//! counting into static buckets, so two runs that observe the same
//! latencies (as the virtual-time scenario guarantees given a seed)
//! render byte-identical histograms.

/// Histogram over millisecond latencies with fixed upper bounds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    bounds_ms: Vec<f64>,
    /// Per-bucket (non-cumulative) counts, one per bound.
    counts: Vec<u64>,
    /// Samples above the last bound.
    overflow: u64,
}

impl LatencyHistogram {
    /// Build from ascending upper bounds (a sample lands in the first
    /// bucket whose bound is >= the sample).
    pub fn new(bounds_ms: Vec<f64>) -> LatencyHistogram {
        assert!(!bounds_ms.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds_ms.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        let counts = vec![0; bounds_ms.len()];
        LatencyHistogram { bounds_ms, counts, overflow: 0 }
    }

    /// The serve default: 0.25 ms to ~8.2 s, doubling (16 buckets).
    pub fn default_serve() -> LatencyHistogram {
        LatencyHistogram::new((0..16).map(|i| 0.25 * (1u64 << i) as f64).collect())
    }

    pub fn record(&mut self, ms: f64) {
        match self.bounds_ms.iter().position(|&b| ms <= b) {
            Some(i) => self.counts[i] += 1,
            None => self.overflow += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.overflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Cumulative `(upper_bound_ms, count_at_or_below)` pairs.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0;
        self.bounds_ms
            .iter()
            .zip(&self.counts)
            .map(|(&b, &c)| {
                acc += c;
                (b, acc)
            })
            .collect()
    }

    /// One greppable line: `le0.25=0 le0.5=2 … overflow=0`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (b, c) in self.cumulative() {
            out.push_str(&format!("le{b}={c} "));
        }
        out.push_str(&format!("overflow={}", self.overflow));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_le_inclusive() {
        let mut h = LatencyHistogram::new(vec![1.0, 2.0, 4.0]);
        h.record(0.5); // le1
        h.record(1.0); // le1 (inclusive)
        h.record(1.01); // le2
        h.record(4.0); // le4
        h.record(4.01); // overflow
        assert_eq!(h.cumulative(), vec![(1.0, 2), (2.0, 3), (4.0, 4)]);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.total(), 5);
    }

    #[test]
    fn default_bounds_double_from_quarter_ms() {
        let h = LatencyHistogram::default_serve();
        let bounds: Vec<f64> = h.cumulative().iter().map(|(b, _)| *b).collect();
        assert_eq!(bounds.len(), 16);
        assert_eq!(bounds[0], 0.25);
        assert_eq!(bounds[1], 0.5);
        assert_eq!(bounds[15], 8192.0);
    }

    #[test]
    fn render_is_greppable_and_deterministic() {
        let mut a = LatencyHistogram::new(vec![1.0, 10.0]);
        let mut b = LatencyHistogram::new(vec![1.0, 10.0]);
        for x in [0.2, 3.0, 5.0, 50.0] {
            a.record(x);
            b.record(x);
        }
        assert_eq!(a.render(), b.render());
        assert_eq!(a.render(), "le1=1 le10=3 overflow=1");
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn unsorted_bounds_panic() {
        LatencyHistogram::new(vec![2.0, 1.0]);
    }
}
