//! Log-bucketed latency histogram for the serve observability surface.
//!
//! Fixed bucket bounds (doubling from 0.25 ms), `le`-style cumulative
//! rendering — one greppable line per snapshot, plus the bucket array
//! the `kforge-serve-v1` JSON summary embeds.  Recording is exact
//! counting into static buckets, so two runs that observe the same
//! latencies (as the virtual-time scenario guarantees given a seed)
//! render byte-identical histograms.

/// A quantile read off bucketed data.  A histogram can only bound a
/// quantile by a bucket edge — and the top bucket is *open*, so a
/// quantile landing there has no upper bound at all.  Reporting the
/// last bounded edge in that case would silently understate tail
/// latency; this type makes the open case explicit instead.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QuantileBound {
    /// The quantile falls in a bounded bucket: value <= this edge.
    Le(f64),
    /// The quantile falls in the open top bucket: all the histogram
    /// can certify is value >= the last edge.
    Above(f64),
}

impl std::fmt::Display for QuantileBound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QuantileBound::Le(b) => write!(f, "<={b}"),
            QuantileBound::Above(b) => write!(f, ">={b}"),
        }
    }
}

/// Histogram over millisecond latencies with fixed upper bounds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    bounds_ms: Vec<f64>,
    /// Per-bucket (non-cumulative) counts, one per bound.
    counts: Vec<u64>,
    /// Samples above the last bound.
    overflow: u64,
}

impl LatencyHistogram {
    /// Build from ascending upper bounds (a sample lands in the first
    /// bucket whose bound is >= the sample).
    pub fn new(bounds_ms: Vec<f64>) -> LatencyHistogram {
        assert!(!bounds_ms.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds_ms.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        let counts = vec![0; bounds_ms.len()];
        LatencyHistogram { bounds_ms, counts, overflow: 0 }
    }

    /// The serve default: 0.25 ms to ~8.2 s, doubling (16 buckets).
    pub fn default_serve() -> LatencyHistogram {
        LatencyHistogram::new((0..16).map(|i| 0.25 * (1u64 << i) as f64).collect())
    }

    pub fn record(&mut self, ms: f64) {
        match self.bounds_ms.iter().position(|&b| ms <= b) {
            Some(i) => self.counts[i] += 1,
            None => self.overflow += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.overflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Cumulative `(upper_bound_ms, count_at_or_below)` pairs.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0;
        self.bounds_ms
            .iter()
            .zip(&self.counts)
            .map(|(&b, &c)| {
                acc += c;
                (b, acc)
            })
            .collect()
    }

    /// The `q`-quantile (q in [0,1]) as a bucket-edge bound, by the
    /// nearest-rank method.  `None` on an empty histogram.  A quantile
    /// whose rank lands among the overflow samples reports
    /// [`QuantileBound::Above`] the last edge — never `Le(last_edge)`,
    /// which would claim an upper bound the data does not support.
    pub fn quantile(&self, q: f64) -> Option<QuantileBound> {
        let total = self.total();
        if total == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (&b, &c) in self.bounds_ms.iter().zip(&self.counts) {
            acc += c;
            if acc >= rank {
                return Some(QuantileBound::Le(b));
            }
        }
        Some(QuantileBound::Above(
            *self.bounds_ms.last().expect("bounds are nonempty by construction"),
        ))
    }

    /// One greppable line: `le0.25=0 le0.5=2 … overflow=0`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (b, c) in self.cumulative() {
            out.push_str(&format!("le{b}={c} "));
        }
        out.push_str(&format!("overflow={}", self.overflow));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_le_inclusive() {
        let mut h = LatencyHistogram::new(vec![1.0, 2.0, 4.0]);
        h.record(0.5); // le1
        h.record(1.0); // le1 (inclusive)
        h.record(1.01); // le2
        h.record(4.0); // le4
        h.record(4.01); // overflow
        assert_eq!(h.cumulative(), vec![(1.0, 2), (2.0, 3), (4.0, 4)]);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.total(), 5);
    }

    #[test]
    fn default_bounds_double_from_quarter_ms() {
        let h = LatencyHistogram::default_serve();
        let bounds: Vec<f64> = h.cumulative().iter().map(|(b, _)| *b).collect();
        assert_eq!(bounds.len(), 16);
        assert_eq!(bounds[0], 0.25);
        assert_eq!(bounds[1], 0.5);
        assert_eq!(bounds[15], 8192.0);
    }

    #[test]
    fn render_is_greppable_and_deterministic() {
        let mut a = LatencyHistogram::new(vec![1.0, 10.0]);
        let mut b = LatencyHistogram::new(vec![1.0, 10.0]);
        for x in [0.2, 3.0, 5.0, 50.0] {
            a.record(x);
            b.record(x);
        }
        assert_eq!(a.render(), b.render());
        assert_eq!(a.render(), "le1=1 le10=3 overflow=1");
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn unsorted_bounds_panic() {
        LatencyHistogram::new(vec![2.0, 1.0]);
    }

    #[test]
    fn quantile_all_samples_above_top_bucket_reports_open_floor() {
        // regression: every sample past the last bound (8192 ms on the
        // serve default) must surface as an explicit ">=8192", not a
        // fabricated "<=8192"
        let mut h = LatencyHistogram::default_serve();
        for _ in 0..100 {
            h.record(10_000.0);
        }
        assert_eq!(h.quantile(0.99), Some(QuantileBound::Above(8192.0)));
        assert_eq!(h.quantile(0.5), Some(QuantileBound::Above(8192.0)));
        assert_eq!(h.quantile(0.99).unwrap().to_string(), ">=8192");
    }

    #[test]
    fn quantile_interior_and_edge_ranks() {
        let mut h = LatencyHistogram::new(vec![1.0, 2.0, 4.0]);
        for _ in 0..50 {
            h.record(0.5); // le1
        }
        for _ in 0..49 {
            h.record(3.0); // le4
        }
        h.record(100.0); // overflow
        // rank(0.5 * 100) = 50 → exactly exhausts the first bucket
        assert_eq!(h.quantile(0.5), Some(QuantileBound::Le(1.0)));
        // rank 51 → first sample of the le4 bucket
        assert_eq!(h.quantile(0.51), Some(QuantileBound::Le(4.0)));
        // rank 99 → still bounded
        assert_eq!(h.quantile(0.99), Some(QuantileBound::Le(4.0)));
        // rank 100 → the overflow sample
        assert_eq!(h.quantile(1.0), Some(QuantileBound::Above(4.0)));
        assert_eq!(h.quantile(0.5).unwrap().to_string(), "<=1");
    }

    #[test]
    fn quantile_empty_and_clamped() {
        let h = LatencyHistogram::default_serve();
        assert_eq!(h.quantile(0.99), None);
        let mut h = LatencyHistogram::new(vec![1.0]);
        h.record(0.5);
        // out-of-range q clamps rather than panicking
        assert_eq!(h.quantile(-3.0), Some(QuantileBound::Le(1.0)));
        assert_eq!(h.quantile(7.0), Some(QuantileBound::Le(1.0)));
    }
}
