//! Metrics: the paper's `fast_p` family (§4.2).
//!
//! `fast_p = (1/N) Σ 1(correct_i ∧ speedup_i > p)` where speedup is
//! baseline-time / candidate-time.  `fast_0` is the correctness rate,
//! `fast_1` on-par performance, `fast_p (p>1)` superior performance.
//!
//! [`hist`] adds the serve path's log-bucketed latency histogram.

pub mod hist;

pub use hist::LatencyHistogram;

/// Outcome of one task: correctness + speedup vs the baseline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskOutcome {
    pub correct: bool,
    /// baseline_time / candidate_time; meaningless when !correct.
    pub speedup: f64,
}

impl TaskOutcome {
    pub fn incorrect() -> TaskOutcome {
        TaskOutcome {
            correct: false,
            speedup: 0.0,
        }
    }

    pub fn correct(speedup: f64) -> TaskOutcome {
        TaskOutcome {
            correct: true,
            speedup,
        }
    }
}

/// fast_p over a set of outcomes.  `fast_0` counts correct regardless
/// of speed (speedup > 0 always holds for a finished run).
pub fn fast_p(outcomes: &[TaskOutcome], p: f64) -> f64 {
    if outcomes.is_empty() {
        return 0.0;
    }
    let hits = outcomes
        .iter()
        .filter(|o| o.correct && o.speedup > p)
        .count();
    hits as f64 / outcomes.len() as f64
}

/// Correctness rate — `fast_0` in the paper's terms.
pub fn correctness_rate(outcomes: &[TaskOutcome]) -> f64 {
    if outcomes.is_empty() {
        return 0.0;
    }
    outcomes.iter().filter(|o| o.correct).count() as f64 / outcomes.len() as f64
}

/// A full fast_p curve over a threshold grid (figures 2–4 plot these).
pub fn curve(outcomes: &[TaskOutcome], thresholds: &[f64]) -> Vec<(f64, f64)> {
    thresholds
        .iter()
        .map(|&p| (p, fast_p(outcomes, p)))
        .collect()
}

/// The standard threshold grid used in the figures.
pub fn standard_thresholds() -> Vec<f64> {
    vec![0.0, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0]
}

/// Continuous speedup distribution (the §8 discussion's finer-grained
/// alternative): sorted speedups of correct tasks.
pub fn speedup_distribution(outcomes: &[TaskOutcome]) -> Vec<f64> {
    let mut xs: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.correct)
        .map(|o| o.speedup)
        .collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<TaskOutcome> {
        vec![
            TaskOutcome::correct(2.0),
            TaskOutcome::correct(1.2),
            TaskOutcome::correct(0.8),
            TaskOutcome::incorrect(),
        ]
    }

    #[test]
    fn fast_p_thresholds() {
        let o = sample();
        assert_eq!(fast_p(&o, 0.0), 0.75); // 3 of 4 correct
        assert_eq!(fast_p(&o, 1.0), 0.5); // 2 beat baseline
        assert_eq!(fast_p(&o, 1.5), 0.25); // 1 at 1.5x
        assert_eq!(fast_p(&o, 3.0), 0.0);
    }

    #[test]
    fn fast_p_monotone_nonincreasing_in_p() {
        let o = sample();
        let c = curve(&o, &standard_thresholds());
        for w in c.windows(2) {
            assert!(w[1].1 <= w[0].1);
        }
    }

    #[test]
    fn correctness_equals_fast0() {
        let o = sample();
        assert_eq!(correctness_rate(&o), fast_p(&o, 0.0));
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(fast_p(&[], 1.0), 0.0);
        assert_eq!(correctness_rate(&[]), 0.0);
    }

    #[test]
    fn distribution_sorted_and_filtered() {
        let d = speedup_distribution(&sample());
        assert_eq!(d, vec![0.8, 1.2, 2.0]);
    }
}
