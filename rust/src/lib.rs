//! # KForge — program synthesis for diverse AI hardware accelerators
//!
//! Reproduction of *KForge* (Sereda et al., 2025): a platform-agnostic
//! two-agent program-synthesis framework.  A **generation agent** `F`
//! iteratively synthesizes kernel programs; a **performance-analysis
//! agent** `G` turns raw profiling data into one actionable
//! recommendation per optimization iteration.
//!
//! This crate is Layer 3 of the three-layer stack (see DESIGN.md):
//! the coordinator, agents, device simulators, profilers, workload
//! suite, verification pipeline and benchmark harness all live here.
//! Layers 1/2 (Pallas kernels + JAX workloads) are build-time Python,
//! AOT-lowered to HLO text and executed from [`runtime`] via PJRT —
//! Python is never on the request path.
//!
//! Module map:
//! - [`util`] — seeded PRNG, JSON/CSV writers, stats, timing (offline
//!   build: the only external crate is a vendored `anyhow` shim).
//! - [`tensor`] — f32 ndarray + reference CPU ops (ground truth).
//! - [`kir`] — the Kernel IR candidate programs are expressed in:
//!   typed graphs, shape inference, validation, interpreter, rewrites.
//! - [`sched`] — the schedule space (tiling, elements-per-thread, …).
//! - [`platform`] — the open platform plugin API: a `Platform` trait +
//!   name registry over data-driven `PlatformSpec`s.  Built-ins: CUDA
//!   (H100), Metal (M4 Max), ROCm (MI300X).  Adding an accelerator is
//!   a one-module change; no other module branches on the platform.
//! - [`perfsim`] — roofline/launch/occupancy device simulator.
//! - [`profiler`] — the open profiler-frontend plugin API: a
//!   `ProfilerFrontend` trait (capture → tool-native artifact →
//!   `Evidence` IR with per-fact fidelity).  Built-ins: nsys CSV,
//!   Xcode screenshot scrape, rocprof trace JSON — selected per
//!   platform via `Platform::profiler_frontend()`.
//! - [`baseline`] — PyTorch-eager, torch.compile and autotuned-search
//!   analogs.
//! - [`search`] — the schedule autotuner: an open `SearchStrategy`
//!   plugin API (beam + evolutionary built-ins) over legality-filtered
//!   schedule moves, a pure cost oracle with optional profiler-Evidence
//!   re-ranking, budget/early-stop control, and store-cached `kforge
//!   tune` runs with golden-pinned `search_frontier_*` artifacts.
//! - [`agents`] — personas (per-platform calibration with a principled
//!   fallback for unseen platforms), generation agent F, analysis
//!   agent G.
//! - [`verify`] — the 5-state verification pipeline (§3.3).
//! - [`workloads`] — the 258-problem suite: KernelBench-KIR levels
//!   1–3 plus the level-4 whole-model tier.
//! - [`model`] — whole-model workloads: a seeded multi-kernel DAG
//!   stitcher, an NNEF-subset text reader, and the pulsed (streaming)
//!   executor with its batch-axis carrier analysis.
//! - [`runtime`] — PJRT artifact loading/execution (real numerics;
//!   behind the `pjrt` cargo feature, stubbed otherwise).
//! - [`coordinator`] — job queue, device-worker pool, experiments.
//! - [`store`] — the synthesis result store: content-addressed job
//!   cache (canonical `JobKey` fingerprints, corruption-tolerant disk
//!   entries) plus crash-safe per-campaign journals behind `--resume`.
//!   One store is shared per process so the harness artifacts and the
//!   conformance gate never compute the same job twice.
//! - [`metrics`] — fast_p and friends.
//! - [`harness`] — regenerates every paper table and figure.
//! - [`conformance`] — the conformance gate: golden paper artifacts
//!   (bless/check with a cell-level differ), per-platform census
//!   artifacts, and the entry points the differential KIR fuzzer and
//!   synthetic workload suites hang off.
//! - [`dist`] — distributed campaigns: a shard planner with
//!   work-stealing chunk claims over the shared cache dir, per-shard
//!   crash-resumable journals, a merge/verify phase provably
//!   bit-identical to the 1-process run, and cross-problem schedule
//!   transfer through the store's family index.
//! - [`serve`] — the production serving tier: bounded two-lane request
//!   queue, admission control with load-shedding and deadlines, a
//!   seeded bursty load generator, the deterministic virtual-time
//!   scenario engine behind `kforge serve --synthetic`, and the
//!   real-time `Service` front end the artifact-replay path runs on.
//! - [`obs`] — self-profiling: the process-wide structured tracer
//!   (scoped spans, counters, gauges under a two-clock determinism
//!   rule), chrome-trace export the rocprof frontend can interpret
//!   back into `Evidence`, trace summarization, and the `KFORGE_LOG`
//!   leveled diagnostics macros.

pub mod util;
pub mod tensor;
pub mod kir;
pub mod sched;
pub mod platform;
pub mod perfsim;
pub mod profiler;
pub mod baseline;
pub mod agents;
pub mod verify;
pub mod workloads;
pub mod model;
pub mod runtime;
pub mod search;
pub mod coordinator;
pub mod store;
pub mod dist;
pub mod metrics;
pub mod harness;
pub mod conformance;
pub mod serve;
pub mod obs;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
