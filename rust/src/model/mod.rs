//! Whole-model workloads: multi-kernel KIR model graphs.
//!
//! The seventh subsystem.  Everything upstream of this module works on
//! single-kernel problems — exactly the KernelBench setting — but the
//! paper's north star is serving real models, where fusion/CSE/
//! scheduling decisions interact *across* kernel boundaries.  This
//! module supplies those workloads in three pieces:
//!
//! - [`generator`] — a seeded stitcher that composes the level-1/2/3
//!   kernel vocabulary (MLP blocks, gated joins, attention heads,
//!   residual adds) into one multi-kernel DAG, lowered to a single
//!   [`crate::kir::Graph`] with named subgraph provenance.
//! - [`nnef`] — a small NNEF-subset text reader, so a committed model
//!   fixture (or a hand-written one) can enter the suite through the
//!   same [`ModelGraph`] type the generator produces.
//! - [`stream`] — pulsed execution: a model whose batch axis is
//!   row-independent is processed in chunks of rows, bit-identical to
//!   whole-graph evaluation.  This is the execution mode the serve
//!   tier's streaming request kind prices and runs.
//!
//! Whole-model problems enter campaigns as the level-4 suite tier
//! ([`crate::workloads::level4`]); the store prices them through the
//! ordinary `JobKey` graph hashes (STORE_SCHEMA v3).

pub mod generator;
pub mod nnef;
pub mod stream;

pub use generator::{generate, ModelConfig, ModelGraph, SubgraphSpan};
pub use nnef::parse as parse_nnef;
pub use stream::{check_streamable, chunk_ranges, is_streamable, stream_eval, with_batch};
