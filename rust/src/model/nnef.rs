//! A small NNEF-subset text reader.
//!
//! Enough of the Khronos NNEF flat syntax to commit whole-model
//! fixtures as text and lower them onto [`crate::kir::Graph`] — not a
//! general importer.  The accepted subset:
//!
//! ```text
//! # block embed                      <- provenance marker (extension)
//! graph tiny_mlp(x) -> (y) {
//!   x  = external(shape = [8, 16]);
//!   w1 = variable(shape = [16, 32], label = "w1");
//!   c  = constant(value = 0.5, shape = [32]);
//!   t  = matmul(x, w1);
//!   t2 = add(t, c);
//!   y  = relu(t2);
//! }
//! ```
//!
//! One statement per line, `;`-terminated.  `external` and `variable`
//! both declare graph inputs (in statement order); `# block <name>`
//! comments open a named provenance span covering the statements that
//! follow.  Supported ops: the nine unary kinds, the five binary
//! kinds, `matmul`, `transpose`, `softmax`, `layer_norm`, `attention`,
//! and `reduce_{sum,max,mean,lse}(x, axis = N)`.  Errors carry the
//! 1-based source line.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::generator::{ModelGraph, SubgraphSpan};
use crate::kir::graph::{GraphBuilder, NodeId};
use crate::kir::op::{BinaryKind, Op, ReduceKind, UnaryKind};
use crate::tensor::Shape;

const UNARY: &[(&str, UnaryKind)] = &[
    ("relu", UnaryKind::Relu),
    ("sigmoid", UnaryKind::Sigmoid),
    ("swish", UnaryKind::Swish),
    ("gelu", UnaryKind::Gelu),
    ("tanh", UnaryKind::Tanh),
    ("exp", UnaryKind::Exp),
    ("neg", UnaryKind::Neg),
    ("square", UnaryKind::Square),
    ("sqrt", UnaryKind::Sqrt),
];

const BINARY: &[(&str, BinaryKind)] = &[
    ("add", BinaryKind::Add),
    ("sub", BinaryKind::Sub),
    ("mul", BinaryKind::Mul),
    ("div", BinaryKind::Div),
    ("max", BinaryKind::Max),
];

const REDUCE: &[(&str, ReduceKind)] = &[
    ("reduce_sum", ReduceKind::Sum),
    ("reduce_max", ReduceKind::Max),
    ("reduce_mean", ReduceKind::Mean),
    ("reduce_lse", ReduceKind::LogSumExp),
];

/// Parse NNEF-subset text into a [`ModelGraph`].
pub fn parse(src: &str) -> Result<ModelGraph> {
    Parser::new(src).run()
}

struct Parser<'a> {
    src: &'a str,
    env: HashMap<String, NodeId>,
    provenance: Vec<SubgraphSpan>,
    block: String,
    block_start: usize,
    node_count: usize,
    results: Vec<String>,
    header_params: Vec<String>,
    externals: Vec<String>,
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Parser<'a> {
        Parser {
            src,
            env: HashMap::new(),
            provenance: Vec::new(),
            block: "graph".into(),
            block_start: 0,
            node_count: 0,
            results: Vec::new(),
            header_params: Vec::new(),
            externals: Vec::new(),
        }
    }

    fn run(mut self) -> Result<ModelGraph> {
        let mut builder: Option<GraphBuilder> = None;
        let mut closed = false;
        for (i, raw) in self.src.lines().enumerate() {
            let lineno = i + 1;
            let line = raw.trim();
            let ctx = || format!("line {lineno}: {line:?}");
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('#') {
                if let Some(name) = rest.trim().strip_prefix("block ") {
                    self.open_block(name.trim());
                }
                continue;
            }
            if closed {
                bail!("line {lineno}: statement after closing brace");
            }
            if builder.is_none() {
                self.parse_header(line).with_context(ctx)?;
                builder = Some(GraphBuilder::new(&self.header_name(line)?));
                continue;
            }
            if line == "}" {
                closed = true;
                continue;
            }
            let b = builder.as_mut().unwrap();
            self.statement(b, line).with_context(ctx)?;
        }
        let Some(b) = builder else { bail!("no graph header found") };
        if !closed {
            bail!("missing closing brace");
        }
        for p in &self.header_params {
            if !self.externals.contains(p) {
                bail!("graph parameter {p:?} was never declared external");
            }
        }
        let mut outputs = Vec::new();
        for r in &self.results {
            let id = self
                .env
                .get(r)
                .copied()
                .with_context(|| format!("graph result {r:?} is undefined"))?;
            outputs.push(id);
        }
        let graph = b.finish(outputs);
        self.close_block(graph.len());
        Ok(ModelGraph { graph, provenance: self.provenance })
    }

    fn open_block(&mut self, name: &str) {
        // close the running span at the current node count
        let here = self.node_count;
        self.close_block(here);
        self.block = name.to_string();
        self.block_start = here;
    }

    fn close_block(&mut self, end: usize) {
        if end > self.block_start {
            self.provenance.push(SubgraphSpan {
                name: std::mem::replace(&mut self.block, "graph".into()),
                start: self.block_start,
                end,
            });
        }
        self.block_start = end;
    }

    fn header_name(&self, line: &str) -> Result<String> {
        let rest = line.strip_prefix("graph ").context("expected `graph`")?;
        let open = rest.find('(').context("expected `(` in graph header")?;
        Ok(rest[..open].trim().to_string())
    }

    fn parse_header(&mut self, line: &str) -> Result<()> {
        let rest = line.strip_prefix("graph ").context("expected `graph <name>(...) -> (...) {`")?;
        let (params, rest) = delimited(rest, '(', ')').context("malformed parameter list")?;
        let rest = rest.trim().strip_prefix("->").context("expected `->`")?;
        let (results, rest) = delimited(rest, '(', ')').context("malformed result list")?;
        if rest.trim() != "{" {
            bail!("expected `{{` after result list");
        }
        self.header_params = idents(params)?;
        self.results = idents(results)?;
        if self.results.is_empty() {
            bail!("graph declares no results");
        }
        Ok(())
    }

    fn statement(&mut self, b: &mut GraphBuilder, line: &str) -> Result<()> {
        let line = line.strip_suffix(';').context("statement must end with `;`")?;
        let (lhs, rhs) = line.split_once('=').context("expected `<id> = <op>(...)`")?;
        let lhs = lhs.trim();
        if !is_ident(lhs) {
            bail!("bad identifier {lhs:?}");
        }
        let rhs = rhs.trim();
        let open = rhs.find('(').context("expected an op invocation")?;
        let op_name = rhs[..open].trim();
        let (args, tail) = delimited(&rhs[open..], '(', ')').context("unbalanced parens")?;
        if !tail.trim().is_empty() {
            bail!("trailing tokens {tail:?}");
        }
        let args = split_args(args);
        let id = self.lower(b, op_name, &args, lhs)?;
        self.node_count = id + 1;
        if self.env.insert(lhs.to_string(), id).is_some() {
            bail!("identifier {lhs:?} redefined");
        }
        Ok(())
    }

    fn lower(
        &mut self,
        b: &mut GraphBuilder,
        op: &str,
        args: &[&str],
        lhs: &str,
    ) -> Result<NodeId> {
        if op == "external" || op == "variable" {
            let shape = attr_shape(args, "shape")?;
            if op == "external" {
                self.externals.push(lhs.to_string());
            }
            return Ok(b.input(shape));
        }
        if op == "constant" {
            let shape = attr_shape(args, "shape")?;
            let value = attr_f64(args, "value")? as f32;
            return Ok(b.push(Op::ConstFill { value, shape }));
        }
        if let Some((_, kind)) = UNARY.iter().find(|(n, _)| *n == op) {
            let [x] = self.operands::<1>(args, op)?;
            return Ok(b.unary(*kind, x));
        }
        if let Some((_, kind)) = BINARY.iter().find(|(n, _)| *n == op) {
            let [x, y] = self.operands::<2>(args, op)?;
            return Ok(b.binary(*kind, x, y));
        }
        if let Some((_, kind)) = REDUCE.iter().find(|(n, _)| *n == op) {
            let [x] = self.operands::<1>(args, op)?;
            let axis = attr_f64(args, "axis")? as usize;
            return Ok(b.reduce(*kind, axis, x));
        }
        match op {
            "matmul" => {
                let [x, y] = self.operands::<2>(args, op)?;
                Ok(b.matmul(x, y))
            }
            "transpose" => {
                let [x] = self.operands::<1>(args, op)?;
                Ok(b.push(Op::Transpose2 { input: x }))
            }
            "softmax" => {
                let [x] = self.operands::<1>(args, op)?;
                Ok(b.push(Op::Softmax { input: x }))
            }
            "layer_norm" => {
                let [x, gamma, beta] = self.operands::<3>(args, op)?;
                Ok(b.push(Op::Layernorm { input: x, gamma, beta }))
            }
            "attention" => {
                let [q, k, v] = self.operands::<3>(args, op)?;
                Ok(b.push(Op::Attention { q, k, v }))
            }
            _ => bail!("unsupported op {op:?}"),
        }
    }

    /// The first N args must be identifiers naming defined nodes
    /// (further args may be `key = value` attributes).
    fn operands<const N: usize>(&self, args: &[&str], op: &str) -> Result<[NodeId; N]> {
        let positional: Vec<&&str> = args.iter().filter(|a| !a.contains('=')).collect();
        if positional.len() != N {
            bail!("{op} wants {N} operand(s), got {}", positional.len());
        }
        let mut out = [0usize; N];
        for (slot, name) in out.iter_mut().zip(positional) {
            *slot = self
                .env
                .get(name.trim())
                .copied()
                .with_context(|| format!("undefined operand {name:?}"))?;
        }
        Ok(out)
    }
}

/// `delimited("(a, b) rest", '(', ')')` → `("a, b", " rest")`.
fn delimited(s: &str, open: char, close: char) -> Option<(&str, &str)> {
    let s = s.trim_start();
    let mut depth = 0usize;
    let start = s.find(open)?;
    if s[..start].trim() != "" {
        return None;
    }
    for (i, c) in s.char_indices().skip(start) {
        if c == open {
            depth += 1;
        } else if c == close {
            depth -= 1;
            if depth == 0 {
                return Some((&s[start + 1..i], &s[i + 1..]));
            }
        }
    }
    None
}

/// Split on top-level commas (brackets and quotes bind tighter).
fn split_args(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let (mut depth, mut quoted, mut last) = (0usize, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => quoted = !quoted,
            '[' | '(' if !quoted => depth += 1,
            ']' | ')' if !quoted => depth = depth.saturating_sub(1),
            ',' if depth == 0 && !quoted => {
                out.push(s[last..i].trim());
                last = i + 1;
            }
            _ => {}
        }
    }
    let tail = s[last..].trim();
    if !tail.is_empty() {
        out.push(tail);
    }
    out
}

fn idents(s: &str) -> Result<Vec<String>> {
    split_args(s)
        .into_iter()
        .map(|p| {
            if is_ident(p) {
                Ok(p.to_string())
            } else {
                bail!("bad identifier {p:?}")
            }
        })
        .collect()
}

fn is_ident(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn attr<'s>(args: &[&'s str], key: &str) -> Result<&'s str> {
    for a in args {
        if let Some((k, v)) = a.split_once('=') {
            if k.trim() == key {
                return Ok(v.trim());
            }
        }
    }
    bail!("missing attribute `{key}`")
}

fn attr_f64(args: &[&str], key: &str) -> Result<f64> {
    let v = attr(args, key)?;
    v.parse::<f64>().with_context(|| format!("attribute `{key}`: bad number {v:?}"))
}

fn attr_shape(args: &[&str], key: &str) -> Result<Shape> {
    let v = attr(args, key)?;
    let inner = v
        .strip_prefix('[')
        .and_then(|v| v.strip_suffix(']'))
        .with_context(|| format!("attribute `{key}` must be a [..] list, got {v:?}"))?;
    let dims: Vec<usize> = split_args(inner)
        .into_iter()
        .map(|d| d.parse::<usize>().with_context(|| format!("bad dim {d:?}")))
        .collect::<Result<_>>()?;
    if dims.is_empty() {
        bail!("attribute `{key}`: empty shape");
    }
    Ok(Shape(dims))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp;
    use crate::kir::validate::validate;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg;

    const TINY: &str = r#"
# block embed
graph tiny(x) -> (y) {
  x  = external(shape = [4, 8]);
  w1 = variable(shape = [8, 16], label = "w1");
  b1 = variable(shape = [16], label = "b1");
  t1 = matmul(x, w1);
  t2 = add(t1, b1);
  h  = gelu(t2);
# block head
  w2 = variable(shape = [16, 8], label = "w2");
  p  = matmul(h, w2);
  s  = softmax(p);
  y  = mul(s, p);
}
"#;

    #[test]
    fn parses_lowered_graph_with_provenance() {
        let m = parse(TINY).unwrap();
        assert_eq!(m.graph.name, "tiny");
        validate(&m.graph).unwrap();
        assert_eq!(m.graph.input_shapes.len(), 4);
        assert_eq!(m.graph.input_shapes[0].dims(), &[4, 8]);
        assert_eq!(m.graph.outputs.len(), 1);
        let names: Vec<&str> = m.provenance.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["embed", "head"]);
        assert_eq!(m.provenance[0].start, 0);
        assert_eq!(m.provenance[1].end, m.graph.len());
        assert_eq!(m.provenance[0].end, m.provenance[1].start);
    }

    #[test]
    fn parsed_model_evaluates() {
        let m = parse(TINY).unwrap();
        let mut rng = Pcg::seed(7);
        let inputs: Vec<Tensor> = m
            .graph
            .input_shapes
            .iter()
            .map(|s| Tensor::randn(s.clone(), &mut rng, 0.5))
            .collect();
        let out = interp::eval(&m.graph, &inputs).unwrap();
        assert_eq!(out[0].shape.dims(), &[4, 8]);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn reduce_constant_and_attention_forms_parse() {
        let src = r#"
graph forms(x) -> (y) {
  x = external(shape = [4, 6]);
  k = variable(shape = [5, 6], label = "k");
  v = variable(shape = [5, 6], label = "v");
  c = constant(value = 0.25, shape = [4, 6]);
  a = attention(x, k, v);
  m = mul(a, c);
  r = reduce_mean(m, axis = 1);
  n = layer_norm_input(m);
  y = add(m, r);
}
"#;
        // layer_norm_input is not an op — the error names the line
        let err = parse(src).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 10"), "{msg}");
        assert!(msg.contains("unsupported op"), "{msg}");
        let fixed = src.replace("  n = layer_norm_input(m);\n", "");
        let m = parse(&fixed).unwrap();
        validate(&m.graph).unwrap();
    }

    #[test]
    fn structural_errors_are_reported_with_lines() {
        for (src, want) in [
            ("graph g(x) -> (y) {\n  y = relu(x);\n}", "undefined operand"),
            ("graph g(x) -> (y) {\n  x = external(shape = [2, 2]);\n}", "result \"y\" is undefined"),
            (
                "graph g(x) -> (y) {\n  y = external(shape = [2]);\n}",
                "parameter \"x\" was never declared",
            ),
            ("graph g(x) -> (y) {\n  x = external(shape = [2, 2]);\n  y = relu(x)\n}", "end with"),
            ("  y = relu(x);\n", "expected `graph"),
        ] {
            let err = parse(src).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(want), "source {src:?}: {msg}");
        }
        assert!(parse("graph g(x) -> (y) {\n  x = external(shape = [2, 2]);")
            .unwrap_err()
            .to_string()
            .contains("missing closing brace"));
    }
}
