//! Seeded whole-model generator: stitches the level-1/2/3 kernel
//! vocabulary into one multi-kernel DAG.
//!
//! Every block operates on a `[batch, d_model]` activation tensor and
//! returns one of the same shape, so blocks compose freely and the
//! stitched graph keeps a single streamed batch axis (see
//! [`super::stream`]).  Weights are declared as graph inputs — the
//! [`crate::workloads::Problem`] convention — and the draw sequence
//! depends only on the seed and the block count, never on the
//! dimensions, so the same seed yields the same *topology* at
//! evaluation and paper-perf scales.

use crate::kir::graph::{Graph, GraphBuilder, NodeId};
use crate::kir::op::{BinaryKind, Op, ReduceKind, UnaryKind};
use crate::tensor::Shape;
use crate::util::rng::Pcg;

/// Named subgraph provenance: the node-id half-open range `[start, end)`
/// a stitched block lowered to.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SubgraphSpan {
    pub name: String,
    pub start: NodeId,
    pub end: NodeId,
}

/// A lowered model: one KIR graph plus the provenance of every block.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub graph: Graph,
    pub provenance: Vec<SubgraphSpan>,
}

impl ModelGraph {
    /// The span covering a node id, if any (the input/weight prelude of
    /// each block belongs to that block's span).
    pub fn span_of(&self, id: NodeId) -> Option<&SubgraphSpan> {
        self.provenance.iter().find(|s| s.start <= id && id < s.end)
    }
}

/// Generation knobs.  `batch`/`d_model` scale the tensors; `blocks` and
/// the head flags shape the topology.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Rows of the streamed activation input.
    pub batch: usize,
    /// Feature width the blocks preserve.
    pub d_model: usize,
    /// Stitched body blocks.
    pub blocks: usize,
    /// Append an attention head (query = activations, keys/values =
    /// weights — row-wise in the batch, so still streamable).
    pub allow_attention: bool,
    /// Append a global-summary head (batch-axis mean folded back in).
    /// This mixes rows, making the model deliberately non-streamable.
    pub allow_global: bool,
}

impl Default for ModelConfig {
    fn default() -> ModelConfig {
        ModelConfig {
            batch: 8,
            d_model: 8,
            blocks: 4,
            allow_attention: false,
            allow_global: false,
        }
    }
}

impl ModelConfig {
    /// The same topology at different tensor scales.
    pub fn scaled(&self, batch: usize, d_model: usize) -> ModelConfig {
        ModelConfig { batch, d_model, ..self.clone() }
    }
}

struct Stitcher {
    b: GraphBuilder,
    rng: Pcg,
    d: usize,
    batch: usize,
    provenance: Vec<SubgraphSpan>,
}

impl Stitcher {
    fn weight(&mut self, dims: &[usize]) -> NodeId {
        self.b.input(Shape::of(dims))
    }

    fn activation(&mut self) -> &'static [UnaryKind] {
        &[
            UnaryKind::Relu,
            UnaryKind::Gelu,
            UnaryKind::Tanh,
            UnaryKind::Sigmoid,
            UnaryKind::Swish,
        ]
    }

    /// h = act(x@W1 + b1); y = h@W2 + b2 — back to width d.
    fn mlp(&mut self, x: NodeId) -> NodeId {
        let f = self.d * (1 + self.rng.below(2) as usize);
        let kinds = self.activation();
        let act = *self.rng.choose(kinds);
        let w1 = self.weight(&[self.d, f]);
        let b1 = self.weight(&[f]);
        let h = self.b.matmul(x, w1);
        let h = self.b.add(h, b1);
        let h = self.b.unary(act, h);
        let w2 = self.weight(&[f, self.d]);
        let b2 = self.weight(&[self.d]);
        let y = self.b.matmul(h, w2);
        self.b.add(y, b2)
    }

    /// y = x + mlp(x) — the residual join.
    fn residual(&mut self, x: NodeId) -> NodeId {
        let inner = self.mlp(x);
        self.b.add(x, inner)
    }

    /// y = (x@Wa + ba) * sigmoid(x@Wb + bb) — fan-out from x, rejoined
    /// multiplicatively (the GLU idiom; a cross-kernel fan-out join).
    fn gated(&mut self, x: NodeId) -> NodeId {
        let wa = self.weight(&[self.d, self.d]);
        let ba = self.weight(&[self.d]);
        let wb = self.weight(&[self.d, self.d]);
        let bb = self.weight(&[self.d]);
        let a = self.b.matmul(x, wa);
        let a = self.b.add(a, ba);
        let g = self.b.matmul(x, wb);
        let g = self.b.add(g, bb);
        let g = self.b.unary(UnaryKind::Sigmoid, g);
        self.b.binary(BinaryKind::Mul, a, g)
    }

    /// t = x@W; y = act(t) + t — one projection consumed by two kernels
    /// (a shared subexpression across the kernel boundary).
    fn shared(&mut self, x: NodeId) -> NodeId {
        let w = self.weight(&[self.d, self.d]);
        let kinds = self.activation();
        let act = *self.rng.choose(kinds);
        let t = self.b.matmul(x, w);
        let a = self.b.unary(act, t);
        self.b.add(a, t)
    }

    /// y = layernorm(x; gamma, beta).
    fn layernorm(&mut self, x: NodeId) -> NodeId {
        let gamma = self.weight(&[self.d]);
        let beta = self.weight(&[self.d]);
        self.b.push(Op::Layernorm { input: x, gamma, beta })
    }

    /// s = softmax(x@Wk); y = s@Wv — an attention-shaped mixer over a
    /// weight codebook (row-wise in the batch).
    fn mixer(&mut self, x: NodeId) -> NodeId {
        let k = self.d * (1 + self.rng.below(2) as usize);
        let wk = self.weight(&[self.d, k]);
        let wv = self.weight(&[k, self.d]);
        let logits = self.b.matmul(x, wk);
        let s = self.b.push(Op::Softmax { input: logits });
        self.b.matmul(s, wv)
    }

    fn block(&mut self, which: u32, x: NodeId) -> (NodeId, &'static str) {
        match which {
            0 => (self.mlp(x), "mlp"),
            1 => (self.residual(x), "residual_mlp"),
            2 => (self.gated(x), "gated"),
            3 => (self.shared(x), "shared_proj"),
            4 => (self.layernorm(x), "layernorm"),
            _ => (self.mixer(x), "softmax_mixer"),
        }
    }
}

/// Generate a seeded whole-model DAG.  Same seed + same block count =>
/// same topology and block sequence, at any `batch`/`d_model`.
pub fn generate(seed: u64, cfg: &ModelConfig) -> ModelGraph {
    assert!(cfg.batch >= 1 && cfg.d_model >= 1 && cfg.blocks >= 1, "degenerate model config");
    let mut st = Stitcher {
        b: GraphBuilder::new(&format!("model_{seed:x}")),
        rng: Pcg::new(seed, 0x4D0D_E1),
        d: cfg.d_model,
        batch: cfg.batch,
        provenance: Vec::new(),
    };
    let batch = st.batch;
    let mut x = st.b.input(Shape::of(&[batch, st.d]));
    st.provenance.push(SubgraphSpan { name: "input".into(), start: 0, end: 1 });
    let mut count = 1usize;
    for i in 0..cfg.blocks {
        // one draw per block regardless of the remap, so topology stays
        // a pure function of (seed, blocks); the first block is never a
        // bare layernorm — every model owns at least one compute anchor
        let mut which = st.rng.below(6);
        if i == 0 && which == 4 {
            which = 0;
        }
        let start = count;
        let (y, name) = st.block(which, x);
        count = y + 1;
        st.provenance.push(SubgraphSpan {
            name: format!("blk{i}:{name}"),
            start,
            end: count,
        });
        x = y;
    }
    if cfg.allow_attention {
        let start = count;
        let sk = st.d * 2;
        let k = st.weight(&[sk, st.d]);
        let v = st.weight(&[sk, st.d]);
        let att = st.b.push(Op::Attention { q: x, k, v });
        x = st.b.add(x, att);
        count = x + 1;
        st.provenance.push(SubgraphSpan {
            name: "head:attention".into(),
            start,
            end: count,
        });
    }
    if cfg.allow_global {
        let start = count;
        let pooled = st.b.reduce(ReduceKind::Mean, 0, x);
        x = st.b.add(x, pooled);
        count = x + 1;
        st.provenance.push(SubgraphSpan {
            name: "head:global_mean".into(),
            start,
            end: count,
        });
    }
    let graph = st.b.finish(vec![x]);
    debug_assert_eq!(count, graph.len(), "provenance spans must cover the graph");
    ModelGraph { graph, provenance: st.provenance }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp;
    use crate::kir::validate::validate;
    use crate::tensor::Tensor;

    fn eval_inputs(g: &Graph, seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg::seed(seed);
        g.input_shapes
            .iter()
            .map(|s| Tensor::randn(s.clone(), &mut rng, 0.4))
            .collect()
    }

    #[test]
    fn deterministic_and_valid() {
        for seed in 0..24 {
            let cfg = ModelConfig::default();
            let a = generate(seed, &cfg);
            let b = generate(seed, &cfg);
            assert_eq!(a.graph, b.graph, "seed {seed}");
            assert_eq!(a.provenance, b.provenance, "seed {seed}");
            validate(&a.graph).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }

    #[test]
    fn provenance_covers_every_node_without_overlap() {
        let m = generate(11, &ModelConfig { blocks: 6, ..Default::default() });
        for id in 0..m.graph.len() {
            assert!(m.span_of(id).is_some(), "node {id} uncovered");
        }
        for w in m.provenance.windows(2) {
            assert_eq!(w[0].end, w[1].start, "spans must tile: {w:?}");
        }
        assert_eq!(m.provenance.last().unwrap().end, m.graph.len());
    }

    #[test]
    fn topology_is_scale_invariant() {
        let cfg = ModelConfig { allow_attention: true, ..Default::default() };
        let small = generate(5, &cfg);
        let big = generate(5, &cfg.scaled(64, 32));
        assert_eq!(small.graph.len(), big.graph.len());
        for (a, b) in small.graph.nodes.iter().zip(big.graph.nodes.iter()) {
            assert_eq!(a.op.mnemonic(), b.op.mnemonic());
        }
        let names =
            |m: &ModelGraph| m.provenance.iter().map(|s| s.name.clone()).collect::<Vec<_>>();
        assert_eq!(names(&small), names(&big));
        assert_eq!(big.graph.input_shapes[0].dim(0), 64);
    }

    #[test]
    fn models_evaluate_finite_on_seeded_inputs() {
        for seed in 0..12 {
            let m = generate(seed, &ModelConfig::default());
            let out = interp::eval(&m.graph, &eval_inputs(&m.graph, seed)).unwrap();
            assert!(
                out.iter().all(|t| t.data.iter().all(|v| v.is_finite())),
                "seed {seed} produced non-finite output"
            );
        }
    }

    #[test]
    fn blocks_vary_with_seed_and_fan_out_joins_exist() {
        let mut kinds = std::collections::BTreeSet::new();
        let mut fan_out = 0usize;
        for seed in 0..40 {
            let m = generate(seed, &ModelConfig { blocks: 5, ..Default::default() });
            for s in &m.provenance {
                if let Some(k) = s.name.split(':').nth(1) {
                    kinds.insert(k.to_string());
                }
            }
            let uses = m.graph.use_counts();
            if m.graph.nodes.iter().enumerate().any(|(i, n)| {
                !matches!(n.op, crate::kir::op::Op::Input { .. }) && uses[i] >= 2
            }) {
                fan_out += 1;
            }
        }
        for want in ["mlp", "residual_mlp", "gated", "shared_proj", "layernorm", "softmax_mixer"] {
            assert!(kinds.contains(want), "block kind {want} never stitched: {kinds:?}");
        }
        assert!(fan_out >= 20, "fan-out joins too rare: {fan_out}/40");
    }

    #[test]
    fn heads_control_streamability() {
        let base = ModelConfig::default();
        let plain = generate(3, &base);
        let att = generate(3, &ModelConfig { allow_attention: true, ..base.clone() });
        let global = generate(3, &ModelConfig { allow_global: true, ..base });
        assert!(super::super::stream::is_streamable(&plain.graph));
        assert!(super::super::stream::is_streamable(&att.graph));
        assert!(!super::super::stream::is_streamable(&global.graph));
    }
}
