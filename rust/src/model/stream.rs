//! Pulsed (streaming) execution of whole-model graphs.
//!
//! A model whose activation input is row-independent along its leading
//! batch axis can be evaluated in chunks of rows — the serve tier's
//! streaming request kind — and the chunked result is **bit-identical**
//! to whole-graph evaluation, because every admitted op applies the
//! same per-row arithmetic in the same order regardless of how many
//! rows sit in the buffer.
//!
//! [`check_streamable`] is a conservative static analysis: it tracks
//! which nodes *carry* the batch axis (dim 0 of graph input 0) and
//! rejects any op that would mix rows (batch-axis reduce/concat,
//! transpose or reshape of a carrier, broadcasts that tie the batch
//! axis to a non-streamed tensor, matmul/attention streaming the wrong
//! side).  [`stream_eval`] then slices input 0 into row chunks,
//! re-infers the graph at each chunk's batch size ([`with_batch`]) and
//! concatenates outputs along axis 0.

use anyhow::{bail, ensure, Context, Result};

use crate::kir::graph::{infer_shape, Graph, Node};
use crate::kir::interp;
use crate::kir::op::Op;
use crate::tensor::Tensor;

/// Check that `g` admits pulsed execution along dim 0 of input 0.
/// Errors name the offending node and rule.
pub fn check_streamable(g: &Graph) -> Result<()> {
    ensure!(!g.input_shapes.is_empty(), "graph has no inputs to stream");
    let s0 = &g.input_shapes[0];
    ensure!(
        s0.rank() >= 2,
        "streamed activation (input 0) must be rank >= 2, got {s0}"
    );
    ensure!(s0.dim(0) >= 1, "streamed batch axis is empty");
    // carrier[i]: node i's dim 0 is the streamed batch axis
    let mut carrier = vec![false; g.len()];
    for (id, node) in g.nodes.iter().enumerate() {
        let rule = |what: &str| -> anyhow::Error {
            anyhow::anyhow!("node {id} ({}): {what}", node.op.mnemonic())
        };
        carrier[id] = match &node.op {
            Op::Input { idx } => *idx == 0,
            Op::ConstFill { .. } => false,
            Op::Unary { input, .. } => carrier[*input],
            Op::Binary { lhs, rhs, .. } => match (carrier[*lhs], carrier[*rhs]) {
                (false, false) => false,
                (true, true) => {
                    if g.node(*lhs).shape.rank() != g.node(*rhs).shape.rank() {
                        return Err(rule("streamed operands of mismatched rank"));
                    }
                    true
                }
                (lc, _) => {
                    let (c, w) = if lc { (*lhs, *rhs) } else { (*rhs, *lhs) };
                    let (cs, ws) = (&g.node(c).shape, &g.node(w).shape);
                    if ws.rank() < cs.rank() || (ws.rank() == cs.rank() && ws.dim(0) == 1) {
                        true
                    } else {
                        return Err(rule(
                            "broadcast ties the batch axis to a non-streamed tensor",
                        ));
                    }
                }
            },
            Op::Matmul { lhs, rhs } => {
                if carrier[*rhs] {
                    return Err(rule("matmul cannot stream its rhs"));
                }
                carrier[*lhs]
            }
            Op::Transpose2 { input } => {
                if carrier[*input] {
                    return Err(rule("transpose moves the batch axis"));
                }
                false
            }
            Op::Reduce { axis, input, .. } => {
                if carrier[*input] && *axis == 0 {
                    return Err(rule("reduce over the batch axis mixes rows"));
                }
                carrier[*input]
            }
            Op::Softmax { input } => carrier[*input],
            Op::Layernorm { input, gamma, beta } => {
                if carrier[*gamma] || carrier[*beta] {
                    return Err(rule("layernorm scale/shift must be weights"));
                }
                carrier[*input]
            }
            Op::Attention { q, k, v } => {
                if carrier[*k] || carrier[*v] {
                    return Err(rule("attention keys/values must be weights"));
                }
                carrier[*q]
            }
            Op::Conv2d { input, weight, .. } | Op::DepthwiseConv2d { input, weight, .. } => {
                if carrier[*weight] {
                    return Err(rule("conv weight must not carry the batch axis"));
                }
                carrier[*input]
            }
            Op::MaxPool2d { input, .. }
            | Op::AvgPool2d { input, .. }
            | Op::GlobalAvgPool { input } => carrier[*input],
            Op::Concat { inputs, axis } => {
                let n_carriers = inputs.iter().filter(|i| carrier[**i]).count();
                if n_carriers == 0 {
                    false
                } else if n_carriers < inputs.len() {
                    return Err(rule("concat mixes streamed and non-streamed tensors"));
                } else if *axis == 0 {
                    return Err(rule("concat along the batch axis reorders rows"));
                } else {
                    true
                }
            }
            Op::Reshape { input, .. } => {
                if carrier[*input] {
                    return Err(rule("reshape of the streamed activation"));
                }
                false
            }
        };
    }
    for &o in &g.outputs {
        if !carrier[o] {
            bail!(
                "output node {o} ({}) does not carry the batch axis",
                g.node(o).op.mnemonic()
            );
        }
    }
    Ok(())
}

/// Convenience predicate over [`check_streamable`].
pub fn is_streamable(g: &Graph) -> bool {
    check_streamable(g).is_ok()
}

/// Half-open row ranges covering `batch` in steps of `chunk_rows`.
pub fn chunk_ranges(batch: usize, chunk_rows: usize) -> Vec<(usize, usize)> {
    let step = chunk_rows.max(1);
    (0..batch.div_ceil(step))
        .map(|i| (i * step, ((i + 1) * step).min(batch)))
        .collect()
}

/// Rebuild `g` with `rows` rows on the streamed batch axis, re-running
/// shape inference over every node.
pub fn with_batch(g: &Graph, rows: usize) -> Result<Graph> {
    ensure!(!g.input_shapes.is_empty(), "graph has no inputs");
    ensure!(rows >= 1, "batch must be at least one row");
    let mut input_shapes = g.input_shapes.clone();
    input_shapes[0].0[0] = rows;
    let mut nodes: Vec<Node> = Vec::with_capacity(g.len());
    for (id, node) in g.nodes.iter().enumerate() {
        let shape = infer_shape(&node.op, &|i| nodes[i].shape.clone(), &input_shapes)
            .with_context(|| format!("re-inferring node {id} at batch {rows}"))?;
        nodes.push(Node { op: node.op.clone(), shape });
    }
    Ok(Graph {
        name: g.name.clone(),
        nodes,
        input_shapes,
        outputs: g.outputs.clone(),
    })
}

/// Evaluate `g` in pulses of `chunk_rows` rows of input 0, stitching
/// outputs back together along axis 0.  Bit-identical to
/// [`interp::eval`] on streamable graphs (see [`check_streamable`]).
pub fn stream_eval(g: &Graph, inputs: &[Tensor], chunk_rows: usize) -> Result<Vec<Tensor>> {
    check_streamable(g)?;
    ensure!(
        inputs.len() == g.input_shapes.len(),
        "expected {} inputs, got {}",
        g.input_shapes.len(),
        inputs.len()
    );
    ensure!(
        inputs[0].shape == g.input_shapes[0],
        "input 0 shape {} does not match declared {}",
        inputs[0].shape,
        g.input_shapes[0]
    );
    let batch = g.input_shapes[0].dim(0);
    // row-major: one row of the activation is a contiguous slab
    let row_stride = inputs[0].shape.numel() / batch;
    let mut out: Option<Vec<Tensor>> = None;
    for (lo, hi) in chunk_ranges(batch, chunk_rows) {
        let rows = hi - lo;
        let pulsed = with_batch(g, rows)?;
        let mut chunk_inputs = inputs.to_vec();
        let mut shape = inputs[0].shape.clone();
        shape.0[0] = rows;
        chunk_inputs[0] = Tensor {
            shape,
            data: inputs[0].data[lo * row_stride..hi * row_stride].to_vec(),
        };
        let res = interp::eval(&pulsed, &chunk_inputs)?;
        match &mut out {
            None => out = Some(res),
            Some(acc) => {
                for (a, r) in acc.iter_mut().zip(res) {
                    a.data.extend_from_slice(&r.data);
                    a.shape.0[0] += r.shape.dim(0);
                }
            }
        }
    }
    out.context("empty batch produced no chunks")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::GraphBuilder;
    use crate::kir::op::{ReduceKind, UnaryKind};
    use crate::model::generator::{generate, ModelConfig};
    use crate::tensor::Shape;
    use crate::util::rng::Pcg;

    fn seeded_inputs(g: &Graph, seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg::seed(seed);
        g.input_shapes
            .iter()
            .map(|s| Tensor::randn(s.clone(), &mut rng, 0.5))
            .collect()
    }

    #[test]
    fn chunk_ranges_cover_the_batch() {
        assert_eq!(chunk_ranges(8, 3), vec![(0, 3), (3, 6), (6, 8)]);
        assert_eq!(chunk_ranges(4, 4), vec![(0, 4)]);
        assert_eq!(chunk_ranges(4, 100), vec![(0, 4)]);
        assert_eq!(chunk_ranges(5, 0), vec![(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
    }

    #[test]
    fn chunked_equals_whole_bit_for_bit() {
        for seed in 0..10u64 {
            let cfg = ModelConfig {
                batch: 8,
                allow_attention: seed % 2 == 0,
                ..Default::default()
            };
            let m = generate(seed, &cfg);
            let inputs = seeded_inputs(&m.graph, seed ^ 0xA5);
            let whole = interp::eval(&m.graph, &inputs).unwrap();
            for chunk_rows in [1, 2, 3, 8, 64] {
                let pulsed = stream_eval(&m.graph, &inputs, chunk_rows).unwrap();
                assert_eq!(whole.len(), pulsed.len());
                for (w, p) in whole.iter().zip(&pulsed) {
                    assert_eq!(w.shape, p.shape, "seed {seed} chunk {chunk_rows}");
                    // bit identity, not approximate closeness
                    let wb: Vec<u32> = w.data.iter().map(|v| v.to_bits()).collect();
                    let pb: Vec<u32> = p.data.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(wb, pb, "seed {seed} chunk {chunk_rows}");
                }
            }
        }
    }

    #[test]
    fn with_batch_rescales_only_the_streamed_axis() {
        let m = generate(2, &ModelConfig::default());
        let wide = with_batch(&m.graph, 32).unwrap();
        assert_eq!(wide.input_shapes[0].dim(0), 32);
        for (orig, re) in m.graph.input_shapes.iter().zip(&wide.input_shapes).skip(1) {
            assert_eq!(orig, re);
        }
        assert_eq!(wide.node(*wide.outputs.first().unwrap()).shape.dim(0), 32);
    }

    #[test]
    fn batch_axis_mixing_is_rejected() {
        // reduce over axis 0 mixes rows
        let mut b = GraphBuilder::new("mix");
        let x = b.input(Shape::of(&[4, 3]));
        let pooled = b.reduce(ReduceKind::Mean, 0, x);
        let y = b.add(x, pooled);
        let g = b.finish(vec![y]);
        let err = check_streamable(&g).unwrap_err().to_string();
        assert!(err.contains("reduce over the batch axis"), "{err}");

        // matmul with a streamed rhs
        let mut b = GraphBuilder::new("rhs");
        let x = b.input(Shape::of(&[4, 4]));
        let w = b.input(Shape::of(&[4, 4]));
        let y = b.matmul(w, x);
        let g = b.finish(vec![y]);
        assert!(!is_streamable(&g));

        // output that never carries the batch axis
        let mut b = GraphBuilder::new("dead");
        let x = b.input(Shape::of(&[4, 3]));
        let w = b.input(Shape::of(&[4, 3]));
        let _ = b.unary(UnaryKind::Relu, x);
        let y = b.unary(UnaryKind::Relu, w);
        let g = b.finish(vec![y]);
        let err = check_streamable(&g).unwrap_err().to_string();
        assert!(err.contains("does not carry the batch axis"), "{err}");
    }

    #[test]
    fn global_head_is_rejected_but_attention_head_streams() {
        let global = generate(4, &ModelConfig { allow_global: true, ..Default::default() });
        assert!(!is_streamable(&global.graph));
        let att = generate(4, &ModelConfig { allow_attention: true, ..Default::default() });
        assert!(is_streamable(&att.graph));
        let inputs = seeded_inputs(&att.graph, 9);
        let whole = interp::eval(&att.graph, &inputs).unwrap();
        let pulsed = stream_eval(&att.graph, &inputs, 2).unwrap();
        assert_eq!(whole[0].data, pulsed[0].data);
    }

    #[test]
    fn nnef_fixture_streams() {
        let m = crate::model::parse_nnef(include_str!(
            "../../fixtures/model/tiny_mlp.nnef"
        ))
        .unwrap();
        check_streamable(&m.graph).unwrap();
        let inputs = seeded_inputs(&m.graph, 3);
        let whole = interp::eval(&m.graph, &inputs).unwrap();
        let pulsed = stream_eval(&m.graph, &inputs, 3).unwrap();
        assert_eq!(whole[0].data, pulsed[0].data);
    }
}
