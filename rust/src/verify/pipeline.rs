//! The verification pipeline: compile → dispatch → numerics.
//!
//! Mirrors the paper's flow: after every generation-evaluation
//! iteration the detailed result is logged and the error channel (if
//! any) feeds the next refinement prompt.  For *correct* programs the
//! pipeline also prices the plan on the simulated device, yielding the
//! measured time that `fast_p` compares against the baseline.

use crate::agents::Program;
use crate::kir::interp;
use crate::kir::validate;
use crate::perfsim::{lower, simulate, SimResult};
use crate::platform::PlatformSpec;
use crate::sched::legal;
use crate::util::rng::Pcg;
use crate::workloads::Problem;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::state::ExecState;

/// Reference outputs are pure functions of (problem, seed); campaigns
/// verify many candidates per problem, so cache them (perf pass §Perf:
/// this halves the interpreter work per verification and amortizes
/// ~40x across personas × iterations).
type IoPair = (Arc<Vec<crate::tensor::Tensor>>, Arc<Vec<crate::tensor::Tensor>>);

fn ref_cache() -> &'static Mutex<HashMap<(String, u64), IoPair>> {
    static REF_CACHE: OnceLock<Mutex<HashMap<(String, u64), IoPair>>> = OnceLock::new();
    REF_CACHE.get_or_init(Default::default)
}

/// (inputs, reference outputs) for a (problem, seed): both are pure and
/// re-requested per candidate, so cached together.
fn reference_io(problem: &Problem, seed: u64) -> IoPair {
    let key = (problem.id.clone(), seed);
    if let Some(hit) = ref_cache().lock().unwrap().get(&key) {
        return hit.clone();
    }
    let inputs = problem.eval_inputs(seed);
    let out = interp::eval(&problem.eval_graph, &inputs)
        .unwrap_or_else(|e| panic!("reference graph for {} failed: {e}", problem.id));
    let pair = (Arc::new(inputs), Arc::new(out));
    ref_cache().lock().unwrap().insert(key, pair.clone());
    pair
}

/// Candidate-independent CSE'd perf graph per problem (§Perf round 2).
fn cse_cache() -> &'static Mutex<HashMap<String, Arc<crate::kir::Graph>>> {
    static PERF_CSE_CACHE: OnceLock<Mutex<HashMap<String, Arc<crate::kir::Graph>>>> =
        OnceLock::new();
    PERF_CSE_CACHE.get_or_init(Default::default)
}

fn cse_perf_graph(problem: &Problem) -> Arc<crate::kir::Graph> {
    if let Some(hit) = cse_cache().lock().unwrap().get(&problem.id) {
        return hit.clone();
    }
    let g = Arc::new(crate::kir::rewrite::cse::eliminate(&problem.perf_graph));
    cse_cache()
        .lock()
        .unwrap()
        .insert(problem.id.clone(), g.clone());
    g
}

/// Numeric tolerances for the correctness check (KernelBench uses
/// atol/rtol 1e-2 on fp32; we are slightly stricter since the
/// interpreter is deterministic, but fast-math still passes).
pub const RTOL: f32 = 1e-2;
pub const ATOL: f32 = 1e-3;

/// Verification result: state + (for correct programs) the simulation.
#[derive(Debug, Clone)]
pub struct VerifyOutput {
    pub state: ExecState,
    /// Present iff state == Correct.
    pub sim: Option<SimResult>,
}

/// Verify a candidate (or a generation failure if `prog` is None).
pub fn verify(
    spec: &PlatformSpec,
    problem: &Problem,
    prog: Option<&Program>,
    rng: &mut Pcg,
) -> VerifyOutput {
    let Some(prog) = prog else {
        return VerifyOutput {
            state: ExecState::GenerationFailure,
            sim: None,
        };
    };

    // 1. compile: structural/type validation of the synthesized graph
    if let Err(e) = validate::validate(&prog.graph) {
        return VerifyOutput {
            state: ExecState::CompilationFailure(e.to_string()),
            sim: None,
        };
    }

    // 2. dispatch: schedule legality on this device
    if let Err(e) = legal::check(&prog.schedule, spec) {
        return VerifyOutput {
            state: ExecState::RuntimeError(e.to_string()),
            sim: None,
        };
    }

    // 3. numerics: evaluate candidate vs reference on seeded inputs
    let (inputs, want) = reference_io(problem, 0xC0FFEE);
    let got = match interp::eval(&prog.graph, &inputs) {
        Ok(g) => g,
        Err(e) => {
            return VerifyOutput {
                state: ExecState::RuntimeError(format!("runtime error: {e}")),
                sim: None,
            };
        }
    };
    if got.len() != want.len() {
        return VerifyOutput {
            state: ExecState::Mismatch(format!(
                "output arity mismatch: got {}, expected {}",
                got.len(),
                want.len()
            )),
            sim: None,
        };
    }
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        if g.shape != w.shape {
            return VerifyOutput {
                state: ExecState::Mismatch(format!(
                    "output {i} shape mismatch: got {}, expected {}",
                    g.shape, w.shape
                )),
                sim: None,
            };
        }
        if !g.allclose(w, RTOL, ATOL) {
            return VerifyOutput {
                state: ExecState::Mismatch(format!(
                    "output {i} numerical mismatch: max |diff| = {:.6}",
                    g.max_abs_diff(w)
                )),
                sim: None,
            };
        }
    }

    // 4. price the correct program on the simulated device.  The
    // schedule was tuned against the perf-scale graph; rewrites the
    // candidate found on the eval graph apply equally at perf scale
    // (same structure), so we re-apply them for pricing.
    let perf_graph = reapply_rewrites(problem, prog);
    let plan = lower::lower(&perf_graph, &prog.schedule);
    let sim = simulate(spec, &plan, rng, crate::baseline::RUNS, crate::baseline::WARMUP);
    VerifyOutput {
        state: ExecState::Correct,
        sim: Some(sim),
    }
}

/// Re-derive the candidate's graph rewrites on the perf-scale graph:
/// if the candidate's eval graph shrank (constant fold / algebraic
/// reduction), apply the same passes to the perf graph.
fn reapply_rewrites(problem: &Problem, prog: &Program) -> crate::kir::Graph {
    use crate::kir::rewrite::{algebraic, constant_fold, cse};
    // "did the candidate discover the rewrite?" — compare the work its
    // eval graph does against the rewritten eval graph's (FLOPs for the
    // algebraic reduction, node count for the constant collapse).
    let candidate_flops = prog.graph.total_flops();
    let mut g = (*cse_perf_graph(problem)).clone();
    if problem.constant_output {
        let folded_eval = constant_fold::fold(&problem.eval_graph);
        if prog.graph.len() <= folded_eval.len() {
            g = constant_fold::fold(&g);
        }
    }
    if problem.reducible {
        let reduced_eval = algebraic::reduce_matmul_chains(&cse::eliminate(&problem.eval_graph));
        if candidate_flops <= reduced_eval.total_flops() * 1.01 {
            g = algebraic::reduce_matmul_chains(&g);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::generation::tests_support::trivial_program;
    use crate::platform::cuda;
    use crate::sched::Schedule;
    use crate::workloads::Suite;

    fn spec() -> PlatformSpec {
        cuda::h100()
    }

    #[test]
    fn generation_failure_state() {
        let suite = Suite::sample(1);
        let mut rng = Pcg::seed(0);
        let out = verify(&spec(), &suite.problems[0], None, &mut rng);
        assert_eq!(out.state.label(), "generation_failure");
        assert!(out.sim.is_none());
    }

    #[test]
    fn correct_program_gets_simulated() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let prog = trivial_program(p);
        let mut rng = Pcg::seed(0);
        let out = verify(&spec(), p, Some(&prog), &mut rng);
        assert!(out.state.is_correct(), "{:?}", out.state);
        assert!(out.sim.unwrap().measured_s > 0.0);
    }

    #[test]
    fn compilation_failure_detected() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let mut prog = trivial_program(p);
        prog.graph.outputs = vec![999];
        let mut rng = Pcg::seed(0);
        let out = verify(&spec(), p, Some(&prog), &mut rng);
        assert_eq!(out.state.label(), "compilation_failure");
        assert!(out.state.error_text().unwrap().contains("error"));
    }

    #[test]
    fn runtime_error_detected() {
        let suite = Suite::sample(1);
        let p = &suite.problems[0];
        let mut prog = trivial_program(p);
        prog.schedule = Schedule {
            threadgroup: 4096,
            ..Schedule::naive()
        };
        let mut rng = Pcg::seed(0);
        let out = verify(&spec(), p, Some(&prog), &mut rng);
        assert_eq!(out.state.label(), "runtime_error");
    }

    #[test]
    fn mismatch_detected() {
        use crate::kir::op::{Op, UnaryKind};
        let suite = Suite::full();
        let p = suite.get("l1_act_swish_0").unwrap();
        let mut prog = trivial_program(p);
        // swap sigmoid for tanh: wrong numerics, same shapes
        for node in prog.graph.nodes.iter_mut() {
            if let Op::Unary { kind, input } = node.op {
                if kind == UnaryKind::Sigmoid {
                    node.op = Op::Unary { kind: UnaryKind::Tanh, input };
                }
            }
        }
        let mut rng = Pcg::seed(0);
        let out = verify(&spec(), p, Some(&prog), &mut rng);
        assert_eq!(out.state.label(), "mismatch", "{:?}", out.state);
    }

    #[test]
    fn reduced_graph_still_verifies_correct_and_prices_cheaper() {
        use crate::kir::rewrite::{algebraic, cse};
        let suite = Suite::full();
        let p = suite.get("l2_012_reduction_chain").unwrap();
        let naive = trivial_program(p);
        let mut reduced = naive.clone();
        reduced.graph = algebraic::reduce_matmul_chains(&cse::eliminate(&p.eval_graph));
        let mut rng = Pcg::seed(0);
        let out_naive = verify(&spec(), p, Some(&naive), &mut rng);
        let out_reduced = verify(&spec(), p, Some(&reduced), &mut rng);
        assert!(out_naive.state.is_correct());
        assert!(out_reduced.state.is_correct(), "{:?}", out_reduced.state);
        assert!(
            out_reduced.sim.unwrap().ideal_s < out_naive.sim.unwrap().ideal_s,
            "reduction should price cheaper"
        );
    }

    #[test]
    fn constant_folded_graph_verifies_and_prices_near_zero() {
        use crate::kir::rewrite::constant_fold;
        let suite = Suite::full();
        let p = suite.get("l2_080_gemm_max_sub_gelu").unwrap();
        let naive = trivial_program(p);
        let mut folded = naive.clone();
        folded.graph = constant_fold::fold(&p.eval_graph);
        let mut rng = Pcg::seed(0);
        let out_naive = verify(&spec(), p, Some(&naive), &mut rng);
        let out_folded = verify(&spec(), p, Some(&folded), &mut rng);
        assert!(out_naive.state.is_correct());
        assert!(out_folded.state.is_correct(), "{:?}", out_folded.state);
        let speedup = out_naive.sim.unwrap().ideal_s / out_folded.sim.unwrap().ideal_s;
        assert!(speedup > 5.0, "constant output should be much faster, got {speedup}");
    }
}
