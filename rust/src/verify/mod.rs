//! Program verification — the paper's §3.3 closed feedback loop.
//!
//! Five execution states: generation failure, compilation failure,
//! runtime error, numerical/shape mismatch, correct.  Every candidate
//! flows through: validate (compile) → schedule legality (dispatch) →
//! interpret + compare vs the reference graph (numerics) — all stages
//! run for real on the synthesized artifact.

pub mod state;
pub mod pipeline;

pub use pipeline::{verify, VerifyOutput};
pub use state::ExecState;
