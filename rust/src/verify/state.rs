//! The five execution states (§3.3).

/// Outcome of one generation-evaluation iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecState {
    /// Network error / model output contains no code.
    GenerationFailure,
    /// Generated code fails to compile (KIR validation error).
    CompilationFailure(String),
    /// Compiles but aborts at dispatch (schedule illegal on device).
    RuntimeError(String),
    /// Runs but output shape/values mismatch the reference.
    Mismatch(String),
    /// Shapes and values match.
    Correct,
}

impl ExecState {
    pub fn is_correct(&self) -> bool {
        matches!(self, ExecState::Correct)
    }

    /// The error text fed back into the next refinement prompt.
    pub fn error_text(&self) -> Option<&str> {
        match self {
            ExecState::GenerationFailure => Some("generation failure: model output contained no code"),
            ExecState::CompilationFailure(e) | ExecState::RuntimeError(e) | ExecState::Mismatch(e) => {
                Some(e)
            }
            ExecState::Correct => None,
        }
    }

    /// Short label for logs / state statistics.
    pub fn label(&self) -> &'static str {
        match self {
            ExecState::GenerationFailure => "generation_failure",
            ExecState::CompilationFailure(_) => "compilation_failure",
            ExecState::RuntimeError(_) => "runtime_error",
            ExecState::Mismatch(_) => "mismatch",
            ExecState::Correct => "correct",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_and_errors() {
        assert!(ExecState::Correct.is_correct());
        assert_eq!(ExecState::Correct.error_text(), None);
        let e = ExecState::RuntimeError("boom".into());
        assert_eq!(e.error_text(), Some("boom"));
        assert_eq!(e.label(), "runtime_error");
        assert!(!e.is_correct());
    }
}
