//! Minimal JSON value, parser and writer.
//!
//! The offline build has no `serde`/`serde_json`; this module covers the
//! two needs we actually have: (a) parsing `artifacts/manifest.json`
//! written by the AOT pipeline, and (b) emitting structured run logs and
//! experiment reports.  It is a full JSON subset parser (objects,
//! arrays, strings with escapes, numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.  Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert (panics on non-object).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 1-space indentation (matches python json.dump(indent=1)).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = " ".repeat(depth + 1);
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(depth));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(depth));
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => anyhow::bail!("expected , or }} found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                other => anyhow::bail!("expected , or ] found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let v = Json::obj()
            .set("a", 1i64)
            .set("b", "x\ny")
            .set("c", Json::Arr(vec![Json::Bool(true), Json::Null, Json::Num(1.5)]));
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj().set("k", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn parses_python_style_manifest() {
        let text = r#"{
 "version": 1,
 "entries": [
  {"key": "swish__ept8__b16", "batch": 16, "is_reference": false,
   "inputs": [{"shape": [16, 16384], "dtype": "float32"}]}
 ]
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").and_then(Json::as_i64), Some(1));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("key").and_then(Json::as_str), Some("swish__ept8__b16"));
        assert_eq!(e.get("is_reference").and_then(Json::as_bool), Some(false));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.iter().map(|j| j.as_i64().unwrap()).collect::<Vec<_>>(), vec![16, 16384]);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("0").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("tab\t quote\" back\\ nl\n unicode\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{]").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} x").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""héllo ∀x""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo ∀x"));
    }
}
