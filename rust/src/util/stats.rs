//! Summary statistics over measurement vectors — the paper averages 100
//! timed runs with 10 warmup steps (§4.1); this module provides the
//! mean/median/percentile machinery the harness uses for that protocol.

/// Summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Compute a summary; panics on an empty slice.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize(empty)");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p50: percentile_sorted(&sorted, 50.0),
        p90: percentile_sorted(&sorted, 90.0),
        p95: percentile_sorted(&sorted, 95.0),
        p99: percentile_sorted(&sorted, 99.0),
        max: sorted[n - 1],
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Arithmetic mean; panics on an empty slice (mirrors [`summarize`]).
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "mean(empty)");
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// The paper's measurement protocol: drop `warmup` leading samples, then
/// report the mean of the rest (100 runs / 10 warmup in §4.1).
pub fn timed_mean(samples: &[f64], warmup: usize) -> f64 {
    let body = &samples[warmup.min(samples.len().saturating_sub(1))..];
    body.iter().sum::<f64>() / body.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_are_ordered() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p95 - 949.05).abs() < 1e-9, "p95={}", s.p95);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 3.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 2.0);
    }

    #[test]
    fn mean_matches_summary_mean() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), summarize(&xs).mean);
        assert_eq!(mean(&[7.0]), 7.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn timed_mean_drops_warmup() {
        // warmup samples are slow (compilation), body is fast
        let samples = [100.0, 100.0, 1.0, 1.0, 1.0];
        assert!((timed_mean(&samples, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn timed_mean_never_empty() {
        assert_eq!(timed_mean(&[5.0], 10), 5.0);
    }

    #[test]
    fn std_zero_for_constant() {
        assert_eq!(summarize(&[3.0; 10]).std, 0.0);
    }
}
