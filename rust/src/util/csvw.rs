//! CSV writer (RFC-4180 quoting) — used by the nsys-like profiler and
//! the benchmark harness to emit the same row-oriented reports the
//! paper's `nsys stats` pipeline produced.

/// In-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity mismatches the header.
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.header.len(),
            "csv row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Render the document.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&render_row(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render_row(row));
            out.push('\n');
        }
        out
    }

    /// Parse a CSV document (used in render→parse round-trip tests and
    /// by the analysis agent when reading nsys-like reports).
    pub fn parse(text: &str) -> anyhow::Result<Csv> {
        let mut lines = split_records(text);
        if lines.is_empty() {
            anyhow::bail!("empty csv");
        }
        let header = lines.remove(0);
        let width = header.len();
        for (i, row) in lines.iter().enumerate() {
            if row.len() != width {
                anyhow::bail!("row {} arity {} != header {}", i, row.len(), width);
            }
        }
        Ok(Csv {
            header,
            rows: lines,
        })
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Typed f64 accessor.
    pub fn f64_at(&self, row: usize, name: &str) -> Option<f64> {
        let c = self.col(name)?;
        self.rows.get(row)?.get(c)?.parse().ok()
    }
}

fn needs_quote(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n')
}

fn render_row(fields: &[String]) -> String {
    fields
        .iter()
        .map(|f| {
            if needs_quote(f) {
                format!("\"{}\"", f.replace('"', "\"\""))
            } else {
                f.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Split text into records honouring quoted fields (newlines inside quotes).
fn split_records(text: &str) -> Vec<Vec<String>> {
    let mut records = Vec::new();
    let mut row = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' if chars.peek() == Some(&'"') => {
                    field.push('"');
                    chars.next();
                }
                '"' => in_quotes = false,
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    if !(row.len() == 1 && row[0].is_empty()) {
                        records.push(std::mem::take(&mut row));
                    } else {
                        row.clear();
                    }
                }
                '\r' => {}
                c => field.push(c),
            }
        }
    }
    if !field.is_empty() || !row.is_empty() {
        row.push(field);
        records.push(row);
    }
    records
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let mut c = Csv::new(&["a", "b"]);
        c.push(vec!["1".into(), "2".into()]);
        c.push(vec!["x,y".into(), "q\"uote".into()]);
        let parsed = Csv::parse(&c.to_string()).unwrap();
        assert_eq!(parsed.header, c.header);
        assert_eq!(parsed.rows, c.rows);
    }

    #[test]
    fn multiline_field() {
        let mut c = Csv::new(&["k"]);
        c.push(vec!["line1\nline2".into()]);
        let parsed = Csv::parse(&c.to_string()).unwrap();
        assert_eq!(parsed.rows[0][0], "line1\nline2");
    }

    #[test]
    #[should_panic]
    fn arity_enforced() {
        let mut c = Csv::new(&["a", "b"]);
        c.push(vec!["only-one".into()]);
    }

    #[test]
    fn typed_access() {
        let mut c = Csv::new(&["name", "time_us"]);
        c.push(vec!["k0".into(), "12.5".into()]);
        assert_eq!(c.f64_at(0, "time_us"), Some(12.5));
        assert_eq!(c.f64_at(0, "missing"), None);
    }

    #[test]
    fn rejects_ragged() {
        assert!(Csv::parse("a,b\n1\n").is_err());
    }
}
