//! Strict CLI flag validation.
//!
//! `flag_value`-style lookup silently ignores anything it does not ask
//! for, so a typo like `--platfrom rocm` used to run the default
//! platform without a word.  Each subcommand now declares its flag set
//! as a [`FlagSpec`]; anything outside it is rejected with an error
//! naming the offending token and the valid set.

use anyhow::{bail, Result};

/// The accepted surface of one subcommand.
#[derive(Debug, Clone, Copy)]
pub struct FlagSpec {
    /// Flags that consume the following token as a value.
    pub value_flags: &'static [&'static str],
    /// Boolean flags (present or absent).
    pub bool_flags: &'static [&'static str],
    /// Maximum bare (non-flag) arguments, e.g. `bench <target>`.
    pub max_positionals: usize,
}

impl FlagSpec {
    fn describe(&self) -> String {
        let mut all: Vec<&str> = self.value_flags.iter().chain(self.bool_flags).copied().collect();
        all.sort_unstable();
        if all.is_empty() {
            "(this subcommand takes no flags)".to_string()
        } else {
            all.join(", ")
        }
    }
}

/// Validate `args` (everything after the subcommand name) against the
/// spec.  Unknown flags, flags missing their value, and surplus
/// positional arguments are all errors naming what was seen and what
/// is valid.
pub fn validate(cmd: &str, args: &[String], spec: &FlagSpec) -> Result<()> {
    let mut positionals = 0usize;
    let mut i = 0;
    while i < args.len() {
        let tok = args[i].as_str();
        if tok.starts_with("--") {
            if spec.value_flags.contains(&tok) {
                match args.get(i + 1) {
                    Some(v) if !v.starts_with("--") => i += 1,
                    _ => bail!("flag {tok} for `kforge {cmd}` requires a value"),
                }
            } else if !spec.bool_flags.contains(&tok) {
                bail!(
                    "unknown flag {tok} for `kforge {cmd}`; valid flags: {}",
                    spec.describe()
                );
            }
        } else {
            positionals += 1;
            if positionals > spec.max_positionals {
                bail!(
                    "unexpected argument {tok:?} for `kforge {cmd}` (takes at most {} positional argument{})",
                    spec.max_positionals,
                    if spec.max_positionals == 1 { "" } else { "s" }
                );
            }
        }
        i += 1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: FlagSpec = FlagSpec {
        value_flags: &["--quick", "--out"],
        bool_flags: &["--bless"],
        max_positionals: 1,
    };

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn accepts_declared_flags_and_positionals() {
        validate("bench", &args(&["fig2", "--quick", "3", "--bless", "--out", "d"]), &SPEC).unwrap();
        validate("bench", &args(&[]), &SPEC).unwrap();
    }

    #[test]
    fn rejects_unknown_flag_naming_it_and_the_valid_set() {
        let e = validate("bench", &args(&["--quack", "3"]), &SPEC).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("--quack"), "{msg}");
        assert!(msg.contains("--quick") && msg.contains("--bless") && msg.contains("--out"), "{msg}");
        assert!(msg.contains("bench"), "{msg}");
    }

    #[test]
    fn rejects_value_flag_without_value() {
        let e = validate("bench", &args(&["--quick"]), &SPEC).unwrap_err();
        assert!(format!("{e:#}").contains("requires a value"));
        // a following flag is not a value
        let e2 = validate("bench", &args(&["--quick", "--bless"]), &SPEC).unwrap_err();
        assert!(format!("{e2:#}").contains("requires a value"));
    }

    #[test]
    fn rejects_surplus_positionals() {
        let e = validate("bench", &args(&["fig2", "fig3"]), &SPEC).unwrap_err();
        assert!(format!("{e:#}").contains("\"fig3\""), "{e:#}");
    }

    #[test]
    fn empty_spec_names_itself() {
        let none = FlagSpec { value_flags: &[], bool_flags: &[], max_positionals: 0 };
        let e = validate("suite", &args(&["--x"]), &none).unwrap_err();
        assert!(format!("{e:#}").contains("takes no flags"));
    }

    #[test]
    fn flag_values_are_not_positionals() {
        // "--out dir" must not count dir toward the positional budget
        let zero = FlagSpec { value_flags: &["--out"], bool_flags: &[], max_positionals: 0 };
        validate("conformance", &args(&["--out", "somedir"]), &zero).unwrap();
    }
}
