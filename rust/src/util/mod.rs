//! Utility substrate: everything the offline build denies us from
//! crates.io — seeded PRNG, JSON reader/writer, CSV writer, summary
//! statistics, wall-clock timing helpers.

pub mod rng;
pub mod json;
pub mod csvw;
pub mod stats;
pub mod timing;
pub mod cliflags;
