//! Deterministic PCG-XSH-RR 64/32 PRNG.
//!
//! Every stochastic decision in the system (agent sampling, measurement
//! noise, workload data) flows from seeded streams of this generator so
//! that every figure and table is bit-reproducible.  Streams are forked
//! hierarchically (`fork("agent")`, `fork("noise")`, …) so adding a
//! consumer never perturbs another consumer's sequence.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Construct from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg { state: 0, inc };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Fork a child stream derived from a label; the child is
    /// independent of further draws from `self`.
    pub fn fork(&self, label: &str) -> Pcg {
        let h = fnv1a(label.as_bytes());
        Pcg::new(self.state ^ h, h | 1)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u32) as i64
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.uniform()).max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/stddev.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Lognormal multiplier with median 1.0 and log-space sigma.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }

    /// Weighted choice over (item, weight) pairs; weights need not sum to 1.
    pub fn choose_weighted<'a, T>(&mut self, xs: &'a [(T, f64)]) -> &'a T {
        let total: f64 = xs.iter().map(|(_, w)| w.max(0.0)).sum();
        assert!(total > 0.0, "all weights zero");
        let mut r = self.uniform() * total;
        for (item, w) in xs {
            r -= w.max(0.0);
            if r <= 0.0 {
                return item;
            }
        }
        &xs[xs.len() - 1].0
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a buffer with standard-normal f32s (workload input data).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * scale;
        }
    }
}

/// FNV-1a 64-bit hash (stream derivation).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seed(42);
        let mut b = Pcg::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::seed(1);
        let mut b = Pcg::seed(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_is_stable_and_independent() {
        let root = Pcg::seed(7);
        let mut c1 = root.fork("agent");
        let mut c2 = root.fork("agent");
        let mut other = root.fork("noise");
        assert_eq!(c1.next_u64(), c2.next_u64());
        assert_ne!(c1.next_u64(), other.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg::seed(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg::seed(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg::seed(9);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 600, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seed(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut r = Pcg::seed(13);
        let mut xs: Vec<f64> = (0..10_001).map(|_| r.lognormal_noise(0.05)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[5000];
        assert!((med - 1.0).abs() < 0.01, "median={med}");
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Pcg::seed(17);
        let items = [("a", 1.0), ("b", 3.0)];
        let mut b_count = 0;
        for _ in 0..10_000 {
            if *r.choose_weighted(&items) == "b" {
                b_count += 1;
            }
        }
        assert!((b_count as f64 / 10_000.0 - 0.75).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seed(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn below_zero_panics() {
        Pcg::seed(0).below(0);
    }
}
