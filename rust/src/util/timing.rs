//! Wall-clock timing helpers for the real-execution paths (PJRT runs,
//! coordinator hot loops) and the harness's before/after perf records.

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` `warmup` times unrecorded, then `runs` times recorded,
/// returning per-run seconds — the paper's 100-run/10-warmup protocol.
pub fn bench_loop<T>(warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        let _ = f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples
}

/// Simple hierarchical stopwatch for coarse phase profiling.
#[derive(Debug, Default)]
pub struct Stopwatch {
    phases: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a named phase.
    pub fn phase<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, secs) = time_it(f);
        self.phases.push((name.to_string(), secs));
        out
    }

    pub fn report(&self) -> String {
        let total: f64 = self.phases.iter().map(|(_, s)| s).sum();
        let mut out = String::new();
        for (name, secs) in &self.phases {
            out.push_str(&format!(
                "{name:<30} {secs:>9.4}s  {:>5.1}%\n",
                100.0 * secs / total.max(1e-12)
            ));
        }
        out.push_str(&format!("{:<30} {total:>9.4}s\n", "TOTAL"));
        out
    }

    pub fn phases(&self) -> &[(String, f64)] {
        &self.phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_loop_counts() {
        let mut calls = 0;
        let samples = bench_loop(3, 5, || calls += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(calls, 8);
    }

    #[test]
    fn stopwatch_report_contains_phases() {
        let mut sw = Stopwatch::new();
        sw.phase("alpha", || ());
        sw.phase("beta", || ());
        let rep = sw.report();
        assert!(rep.contains("alpha") && rep.contains("beta") && rep.contains("TOTAL"));
    }
}
